//! Training: the [`Optimizer`] trait the host trainer is generic over
//! (with [`Sgd`]/[`Adam`] impls), [`LossHead`] objectives, the engine
//! path's whole-model optimizer ([`ModelOptimizer`] + [`ModelOpt`]),
//! gradient clipping, and the epoch drivers. The artifact-free
//! interpreter path lives in [`host`].

pub mod host;
pub mod loss;
pub mod optim;

use anyhow::{bail, Result};

use crate::exec::{Engine, StepResult};
use crate::graph::Dataset;
use crate::models::{HeadKind, Model, ParamSet};

pub use loss::{LossHead, LossStats};
pub use optim::{Adam, Optimizer, Sgd};

/// `train.optimizer` values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    Adam,
}

impl OptimKind {
    pub fn parse(s: &str) -> Option<OptimKind> {
        match s {
            "sgd" => Some(OptimKind::Sgd),
            "adam" => Some(OptimKind::Adam),
            _ => None,
        }
    }
}

/// `train.loss` values (resolved to a width-carrying [`LossHead`] by
/// [`TrainConfig::loss_head`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    Sum,
    Classifier,
    PerVertex,
}

impl LossKind {
    pub fn parse(s: &str) -> Option<LossKind> {
        match s {
            "sum" => Some(LossKind::Sum),
            "classifier" => Some(LossKind::Classifier),
            "pervertex" => Some(LossKind::PerVertex),
            _ => None,
        }
    }
}

/// The typed `train.*` config section (mirrors `serve.*`): optimizer
/// selection, learning-rate and Adam moments, epoch count and loss head.
/// Every key validates at apply time with the offending key named;
/// cross-field bounds (betas without Adam) are checked by
/// [`TrainConfig::validate`] once every key has applied.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub optimizer: OptimKind,
    pub lr: f32,
    /// Adam first-moment decay (`None` = the 0.9 default). Setting it
    /// under `train.optimizer=sgd` is a cross-field error.
    pub beta1: Option<f32>,
    /// Adam second-moment decay (`None` = the 0.999 default).
    pub beta2: Option<f32>,
    pub epochs: usize,
    /// `None` derives the head from the model-level `head` key.
    pub loss: Option<LossKind>,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            optimizer: OptimKind::Sgd,
            lr: 0.05,
            beta1: None,
            beta2: None,
            epochs: 3,
            loss: None,
        }
    }
}

impl TrainConfig {
    /// Cross-field bounds, run after the whole config has applied.
    pub fn validate(&self) -> Result<()> {
        if self.optimizer == OptimKind::Sgd
            && (self.beta1.is_some() || self.beta2.is_some())
        {
            bail!(
                "train.beta1/train.beta2 only apply to \
                 train.optimizer=adam (got train.optimizer=sgd)"
            );
        }
        Ok(())
    }

    /// The configured host-path update rule, boxed for config-driven
    /// selection ([`HostTrainer`] stays generic over it).
    ///
    /// [`HostTrainer`]: crate::train::host::HostTrainer
    pub fn make_optimizer(&self) -> Box<dyn Optimizer> {
        match self.optimizer {
            OptimKind::Sgd => Box::new(Sgd::new(self.lr)),
            OptimKind::Adam => Box::new(Adam::with_betas(
                self.lr,
                self.beta1.unwrap_or(0.9),
                self.beta2.unwrap_or(0.999),
            )),
        }
    }

    /// The same selection for the engine path's closed rule set.
    pub fn model_optimizer(&self) -> ModelOptimizer {
        match self.optimizer {
            OptimKind::Sgd => ModelOptimizer::sgd(self.lr),
            OptimKind::Adam => ModelOptimizer::Adam {
                lr: self.lr,
                beta1: self.beta1.unwrap_or(0.9),
                beta2: self.beta2.unwrap_or(0.999),
                eps: 1e-8,
            },
        }
    }

    /// Resolve the loss head: an explicit `train.loss` wins, otherwise
    /// the model-level `head` kind maps across (`lm` predicts the
    /// vocabulary per vertex, `classifier` reads `n_classes` logits at
    /// the root).
    pub fn loss_head(
        &self,
        head: HeadKind,
        n_classes: usize,
        vocab: usize,
    ) -> LossHead {
        let kind = self.loss.unwrap_or(match head {
            HeadKind::SumRootState => LossKind::Sum,
            HeadKind::ClassifierAtRoot => LossKind::Classifier,
            HeadKind::LmPerVertex => LossKind::PerVertex,
        });
        match kind {
            LossKind::Sum => LossHead::SumRootState,
            LossKind::Classifier => LossHead::ClassifierAtRoot { n_classes },
            LossKind::PerVertex => LossHead::PerVertex { n_classes: vocab },
        }
    }
}

/// The engine path's closed set of update rules, applied whole-model by
/// [`ModelOpt`] (cell + head + embedding stores at once). The open,
/// host-path counterpart is the [`Optimizer`] trait. Renamed from
/// `train::Optimizer` when the trait took that name.
#[derive(Debug, Clone, Copy)]
pub enum ModelOptimizer {
    Sgd { lr: f32, momentum: f32 },
    Adagrad { lr: f32, eps: f32 },
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl ModelOptimizer {
    pub fn sgd(lr: f32) -> ModelOptimizer {
        ModelOptimizer::Sgd { lr, momentum: 0.0 }
    }

    pub fn adam(lr: f32) -> ModelOptimizer {
        ModelOptimizer::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Per-tensor optimizer slots (momentum / second-moment accumulators).
#[derive(Debug, Default)]
pub struct OptState {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl OptState {
    fn ensure(&mut self, sizes: &[usize]) {
        if self.m.len() != sizes.len() {
            self.m = sizes.iter().map(|&n| vec![0.0; n]).collect();
            self.v = sizes.iter().map(|&n| vec![0.0; n]).collect();
        }
    }

    /// Apply one update to `params` from `grads` (flat, same layout).
    pub fn step_tensors(
        &mut self,
        opt: ModelOptimizer,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
    ) {
        let sizes: Vec<usize> = params.iter().map(Vec::len).collect();
        self.ensure(&sizes);
        self.t += 1;
        match opt {
            ModelOptimizer::Sgd { lr, momentum } => {
                for (i, p) in params.iter_mut().enumerate() {
                    let g = &grads[i];
                    if momentum == 0.0 {
                        for (w, &gi) in p.iter_mut().zip(g) {
                            *w -= lr * gi;
                        }
                    } else {
                        let m = &mut self.m[i];
                        for ((w, &gi), mi) in p.iter_mut().zip(g).zip(m.iter_mut()) {
                            *mi = momentum * *mi + gi;
                            *w -= lr * *mi;
                        }
                    }
                }
            }
            ModelOptimizer::Adagrad { lr, eps } => {
                for (i, p) in params.iter_mut().enumerate() {
                    let g = &grads[i];
                    let v = &mut self.v[i];
                    for ((w, &gi), vi) in p.iter_mut().zip(g).zip(v.iter_mut()) {
                        *vi += gi * gi;
                        *w -= lr * gi / (vi.sqrt() + eps);
                    }
                }
            }
            ModelOptimizer::Adam { lr, beta1, beta2, eps } => {
                let bc1 = 1.0 - beta1.powi(self.t as i32);
                let bc2 = 1.0 - beta2.powi(self.t as i32);
                for (i, p) in params.iter_mut().enumerate() {
                    let g = &grads[i];
                    let (m, v) = (&mut self.m[i], &mut self.v[i]);
                    for (((w, &gi), mi), vi) in
                        p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
                    {
                        *mi = beta1 * *mi + (1.0 - beta1) * gi;
                        *vi = beta2 * *vi + (1.0 - beta2) * gi * gi;
                        let mhat = *mi / bc1;
                        let vhat = *vi / bc2;
                        *w -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
    }
}

/// Optimizer state for a whole model (cell params + head + embedding).
#[derive(Debug, Default)]
pub struct ModelOpt {
    cell: OptState,
    head: OptState,
    emb: OptState,
}

impl ModelOpt {
    /// One optimizer step; invalidates device buffers of mutated params.
    pub fn step(&mut self, opt: ModelOptimizer, model: &mut Model, grad_scale: f32) {
        scale_set(&mut model.params, grad_scale);
        self.cell
            .step_tensors(opt, &mut model.params.host, &model.params.grad);
        model.params.invalidate();
        if let Some(head) = &mut model.head {
            scale_set(head, grad_scale);
            self.head.step_tensors(opt, &mut head.host, &head.grad);
            head.invalidate();
        }
        {
            let e = &mut model.embedding;
            if grad_scale != 1.0 {
                for g in e.grad.iter_mut() {
                    *g *= grad_scale;
                }
            }
            let mut p = std::mem::take(&mut e.table);
            let g = std::mem::take(&mut e.grad);
            self.emb.step_tensors(
                opt,
                std::slice::from_mut(&mut p),
                std::slice::from_ref(&g),
            );
            e.table = p;
            e.grad = g;
        }
        model.zero_grads();
    }
}

fn scale_set(p: &mut ParamSet, s: f32) {
    if s != 1.0 {
        for g in &mut p.grad {
            for v in g.iter_mut() {
                *v *= s;
            }
        }
    }
}

/// Clip the global grad norm of all stores to `max_norm`; returns the
/// scale applied (1.0 if under the limit).
pub fn clip_scale(model: &Model, max_norm: f32) -> f32 {
    let mut sq = model.params.grad_norm().powi(2);
    if let Some(h) = &model.head {
        sq += h.grad_norm().powi(2);
    }
    sq += model.embedding.grad.iter().map(|x| x * x).sum::<f32>();
    let norm = sq.sqrt();
    if norm > max_norm && norm > 0.0 {
        max_norm / norm
    } else {
        1.0
    }
}

/// One epoch record for loss-curve logging.
#[derive(Debug, Clone)]
pub struct EpochLog {
    pub epoch: usize,
    pub loss_per_label: f32,
    pub accuracy: f32,
    pub seconds: f64,
    pub n_vertices: usize,
}

/// Train `model` on `data` for `epochs`, logging per-epoch averages.
pub fn train_epochs(
    engine: &mut Engine<'_>,
    model: &mut Model,
    data: &Dataset,
    bs: usize,
    opt: ModelOptimizer,
    epochs: usize,
    max_grad_norm: f32,
    mut on_epoch: impl FnMut(&EpochLog),
) -> Result<Vec<EpochLog>> {
    let mut opt_state = ModelOpt::default();
    let mut logs = Vec::with_capacity(epochs);
    for epoch in 0..epochs {
        let t0 = std::time::Instant::now();
        let mut loss = 0.0f64;
        let mut ncorrect = 0.0f64;
        let mut n_labels = 0usize;
        let mut n_vertices = 0usize;
        for mb in data.minibatches(bs) {
            let r: StepResult = engine.run_minibatch(model, &mb)?;
            loss += r.loss as f64;
            ncorrect += r.ncorrect as f64;
            n_labels += r.n_labels.max(
                // Tree-FC's synthetic objective has no labels; count roots
                if r.n_labels == 0 { mb.len() } else { 0 },
            );
            n_vertices += r.n_vertices;
            let scale = clip_scale(model, max_grad_norm);
            opt_state.step(opt, model, scale);
        }
        let log = EpochLog {
            epoch,
            loss_per_label: (loss / n_labels.max(1) as f64) as f32,
            accuracy: (ncorrect / n_labels.max(1) as f64) as f32,
            seconds: t0.elapsed().as_secs_f64(),
            n_vertices,
        };
        on_epoch(&log);
        logs.push(log);
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_decreases_quadratic() {
        // minimize 0.5*(w-3)^2 with exact gradient w-3
        let mut st = OptState::default();
        let mut p = vec![vec![0.0f32]];
        for _ in 0..200 {
            let g = vec![vec![p[0][0] - 3.0]];
            st.step_tensors(ModelOptimizer::sgd(0.1), &mut p, &g);
        }
        assert!((p[0][0] - 3.0).abs() < 1e-3, "{}", p[0][0]);
    }

    #[test]
    fn momentum_matches_hand_rolled() {
        let mut st = OptState::default();
        let mut p = vec![vec![1.0f32]];
        let opt = ModelOptimizer::Sgd { lr: 0.1, momentum: 0.9 };
        // two steps with constant gradient 1.0
        st.step_tensors(opt, &mut p, &[vec![1.0]]);
        assert!((p[0][0] - 0.9).abs() < 1e-6);
        st.step_tensors(opt, &mut p, &[vec![1.0]]);
        // velocity = 0.9*1 + 1 = 1.9 ; w = 0.9 - 0.19
        assert!((p[0][0] - 0.71).abs() < 1e-6);
    }

    #[test]
    fn adam_converges_fast() {
        let mut st = OptState::default();
        let mut p = vec![vec![-4.0f32]];
        for _ in 0..400 {
            let g = vec![vec![2.0 * p[0][0]]]; // minimize w^2
            st.step_tensors(ModelOptimizer::adam(0.05), &mut p, &g);
        }
        assert!(p[0][0].abs() < 1e-2, "{}", p[0][0]);
    }

    #[test]
    fn adagrad_step_shrinks() {
        let mut st = OptState::default();
        let mut p = vec![vec![0.0f32]];
        let opt = ModelOptimizer::Adagrad { lr: 1.0, eps: 1e-8 };
        st.step_tensors(opt, &mut p, &[vec![1.0]]);
        let first = -p[0][0];
        let before = p[0][0];
        st.step_tensors(opt, &mut p, &[vec![1.0]]);
        let second = before - p[0][0];
        assert!(second < first, "adagrad steps must shrink: {first} {second}");
    }
}
