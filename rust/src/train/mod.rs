//! Training: host-side optimizers (SGD/momentum, Adagrad, Adam), gradient
//! clipping, and the epoch driver that ties scheduler + engine + optimizer
//! together. The artifact-free interpreter path lives in [`host`].

pub mod host;

use anyhow::Result;

use crate::exec::{Engine, StepResult};
use crate::graph::Dataset;
use crate::models::{Model, ParamSet};

#[derive(Debug, Clone, Copy)]
pub enum Optimizer {
    Sgd { lr: f32, momentum: f32 },
    Adagrad { lr: f32, eps: f32 },
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl Optimizer {
    pub fn sgd(lr: f32) -> Optimizer {
        Optimizer::Sgd { lr, momentum: 0.0 }
    }

    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Per-tensor optimizer slots (momentum / second-moment accumulators).
#[derive(Debug, Default)]
pub struct OptState {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl OptState {
    fn ensure(&mut self, sizes: &[usize]) {
        if self.m.len() != sizes.len() {
            self.m = sizes.iter().map(|&n| vec![0.0; n]).collect();
            self.v = sizes.iter().map(|&n| vec![0.0; n]).collect();
        }
    }

    /// Apply one update to `params` from `grads` (flat, same layout).
    pub fn step_tensors(
        &mut self,
        opt: Optimizer,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
    ) {
        let sizes: Vec<usize> = params.iter().map(Vec::len).collect();
        self.ensure(&sizes);
        self.t += 1;
        match opt {
            Optimizer::Sgd { lr, momentum } => {
                for (i, p) in params.iter_mut().enumerate() {
                    let g = &grads[i];
                    if momentum == 0.0 {
                        for (w, &gi) in p.iter_mut().zip(g) {
                            *w -= lr * gi;
                        }
                    } else {
                        let m = &mut self.m[i];
                        for ((w, &gi), mi) in p.iter_mut().zip(g).zip(m.iter_mut()) {
                            *mi = momentum * *mi + gi;
                            *w -= lr * *mi;
                        }
                    }
                }
            }
            Optimizer::Adagrad { lr, eps } => {
                for (i, p) in params.iter_mut().enumerate() {
                    let g = &grads[i];
                    let v = &mut self.v[i];
                    for ((w, &gi), vi) in p.iter_mut().zip(g).zip(v.iter_mut()) {
                        *vi += gi * gi;
                        *w -= lr * gi / (vi.sqrt() + eps);
                    }
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps } => {
                let bc1 = 1.0 - beta1.powi(self.t as i32);
                let bc2 = 1.0 - beta2.powi(self.t as i32);
                for (i, p) in params.iter_mut().enumerate() {
                    let g = &grads[i];
                    let (m, v) = (&mut self.m[i], &mut self.v[i]);
                    for (((w, &gi), mi), vi) in
                        p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
                    {
                        *mi = beta1 * *mi + (1.0 - beta1) * gi;
                        *vi = beta2 * *vi + (1.0 - beta2) * gi * gi;
                        let mhat = *mi / bc1;
                        let vhat = *vi / bc2;
                        *w -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
    }
}

/// Optimizer state for a whole model (cell params + head + embedding).
#[derive(Debug, Default)]
pub struct ModelOpt {
    cell: OptState,
    head: OptState,
    emb: OptState,
}

impl ModelOpt {
    /// One optimizer step; invalidates device buffers of mutated params.
    pub fn step(&mut self, opt: Optimizer, model: &mut Model, grad_scale: f32) {
        scale_set(&mut model.params, grad_scale);
        self.cell
            .step_tensors(opt, &mut model.params.host, &model.params.grad);
        model.params.invalidate();
        if let Some(head) = &mut model.head {
            scale_set(head, grad_scale);
            self.head.step_tensors(opt, &mut head.host, &head.grad);
            head.invalidate();
        }
        {
            let e = &mut model.embedding;
            if grad_scale != 1.0 {
                for g in e.grad.iter_mut() {
                    *g *= grad_scale;
                }
            }
            let mut p = std::mem::take(&mut e.table);
            let g = std::mem::take(&mut e.grad);
            self.emb.step_tensors(
                opt,
                std::slice::from_mut(&mut p),
                std::slice::from_ref(&g),
            );
            e.table = p;
            e.grad = g;
        }
        model.zero_grads();
    }
}

fn scale_set(p: &mut ParamSet, s: f32) {
    if s != 1.0 {
        for g in &mut p.grad {
            for v in g.iter_mut() {
                *v *= s;
            }
        }
    }
}

/// Clip the global grad norm of all stores to `max_norm`; returns the
/// scale applied (1.0 if under the limit).
pub fn clip_scale(model: &Model, max_norm: f32) -> f32 {
    let mut sq = model.params.grad_norm().powi(2);
    if let Some(h) = &model.head {
        sq += h.grad_norm().powi(2);
    }
    sq += model.embedding.grad.iter().map(|x| x * x).sum::<f32>();
    let norm = sq.sqrt();
    if norm > max_norm && norm > 0.0 {
        max_norm / norm
    } else {
        1.0
    }
}

/// One epoch record for loss-curve logging.
#[derive(Debug, Clone)]
pub struct EpochLog {
    pub epoch: usize,
    pub loss_per_label: f32,
    pub accuracy: f32,
    pub seconds: f64,
    pub n_vertices: usize,
}

/// Train `model` on `data` for `epochs`, logging per-epoch averages.
pub fn train_epochs(
    engine: &mut Engine<'_>,
    model: &mut Model,
    data: &Dataset,
    bs: usize,
    opt: Optimizer,
    epochs: usize,
    max_grad_norm: f32,
    mut on_epoch: impl FnMut(&EpochLog),
) -> Result<Vec<EpochLog>> {
    let mut opt_state = ModelOpt::default();
    let mut logs = Vec::with_capacity(epochs);
    for epoch in 0..epochs {
        let t0 = std::time::Instant::now();
        let mut loss = 0.0f64;
        let mut ncorrect = 0.0f64;
        let mut n_labels = 0usize;
        let mut n_vertices = 0usize;
        for mb in data.minibatches(bs) {
            let r: StepResult = engine.run_minibatch(model, &mb)?;
            loss += r.loss as f64;
            ncorrect += r.ncorrect as f64;
            n_labels += r.n_labels.max(
                // Tree-FC's synthetic objective has no labels; count roots
                if r.n_labels == 0 { mb.len() } else { 0 },
            );
            n_vertices += r.n_vertices;
            let scale = clip_scale(model, max_grad_norm);
            opt_state.step(opt, model, scale);
        }
        let log = EpochLog {
            epoch,
            loss_per_label: (loss / n_labels.max(1) as f64) as f32,
            accuracy: (ncorrect / n_labels.max(1) as f64) as f32,
            seconds: t0.elapsed().as_secs_f64(),
            n_vertices,
        };
        on_epoch(&log);
        logs.push(log);
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_decreases_quadratic() {
        // minimize 0.5*(w-3)^2 with exact gradient w-3
        let mut st = OptState::default();
        let mut p = vec![vec![0.0f32]];
        for _ in 0..200 {
            let g = vec![vec![p[0][0] - 3.0]];
            st.step_tensors(Optimizer::sgd(0.1), &mut p, &g);
        }
        assert!((p[0][0] - 3.0).abs() < 1e-3, "{}", p[0][0]);
    }

    #[test]
    fn momentum_matches_hand_rolled() {
        let mut st = OptState::default();
        let mut p = vec![vec![1.0f32]];
        let opt = Optimizer::Sgd { lr: 0.1, momentum: 0.9 };
        // two steps with constant gradient 1.0
        st.step_tensors(opt, &mut p, &[vec![1.0]]);
        assert!((p[0][0] - 0.9).abs() < 1e-6);
        st.step_tensors(opt, &mut p, &[vec![1.0]]);
        // velocity = 0.9*1 + 1 = 1.9 ; w = 0.9 - 0.19
        assert!((p[0][0] - 0.71).abs() < 1e-6);
    }

    #[test]
    fn adam_converges_fast() {
        let mut st = OptState::default();
        let mut p = vec![vec![-4.0f32]];
        for _ in 0..400 {
            let g = vec![vec![2.0 * p[0][0]]]; // minimize w^2
            st.step_tensors(Optimizer::adam(0.05), &mut p, &g);
        }
        assert!(p[0][0].abs() < 1e-2, "{}", p[0][0]);
    }

    #[test]
    fn adagrad_step_shrinks() {
        let mut st = OptState::default();
        let mut p = vec![vec![0.0f32]];
        let opt = Optimizer::Adagrad { lr: 1.0, eps: 1e-8 };
        st.step_tensors(opt, &mut p, &[vec![1.0]]);
        let first = -p[0][0];
        let before = p[0][0];
        st.step_tensors(opt, &mut p, &[vec![1.0]]);
        let second = before - p[0][0];
        assert!(second < first, "adagrad steps must shrink: {first} {second}");
    }
}
