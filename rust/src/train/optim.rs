//! The host-path [`Optimizer`] trait: the update rule [`HostTrainer`] is
//! generic over, the way `serve::Server` is generic over `FormPolicy`.
//!
//! Implementations are stateful and keyed by a dense, stable tensor
//! `slot` (cell parameters first, the embedding table last), so moment
//! buffers are allocated once on the first step and recycled forever
//! after — the Adam + loss-head training loop stays inside the
//! zero-steady-state-allocation envelope (DESIGN.md §5). Updates run
//! sequentially on the coordinator, so every rule is bitwise identical
//! across thread counts by construction.
//!
//! [`HostTrainer`]: crate::train::host::HostTrainer

/// A stateful tensor-wise update rule.
pub trait Optimizer {
    /// Name for logs and bench records (`"sgd"`, `"adam"`).
    fn name(&self) -> &'static str;

    /// Called once per minibatch step, before any [`update`]
    /// (stateful rules advance their timestep here — Adam's bias
    /// correction depends on it).
    ///
    /// [`update`]: Optimizer::update
    fn begin_step(&mut self) {}

    /// Apply one update to `param` in place from `grad` (same length).
    /// `slot` identifies the tensor across steps: dense, stable, cell
    /// parameters in declaration order with the embedding table after.
    fn update(&mut self, slot: usize, param: &mut [f32], grad: &[f32]);
}

/// Plain stochastic gradient descent, `w -= lr * g`. Stateless — this is
/// exactly the update [`HostTrainer`] hard-coded before the trait
/// existed, so default-configured training curves are unchanged.
///
/// [`HostTrainer`]: crate::train::host::HostTrainer
#[derive(Debug, Clone, Copy)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Sgd {
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn update(&mut self, _slot: usize, param: &mut [f32], grad: &[f32]) {
        let lr = self.lr;
        for (w, &g) in param.iter_mut().zip(grad) {
            *w -= lr * g;
        }
    }
}

/// Adam (Kingma & Ba) with bias correction. First and second moment
/// buffers are per-slot `Vec`s sized on first use and recycled on every
/// later step — zero steady-state allocation.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam::with_betas(lr, 0.9, 0.999)
    }

    pub fn with_betas(lr: f32, beta1: f32, beta2: f32) -> Adam {
        Adam { lr, beta1, beta2, eps: 1e-8, t: 0, m: Vec::new(), v: Vec::new() }
    }

    /// Steps taken so far (tests assert moment recycling against it).
    pub fn steps(&self) -> u64 {
        self.t
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn update(&mut self, slot: usize, param: &mut [f32], grad: &[f32]) {
        while self.m.len() <= slot {
            self.m.push(Vec::new());
            self.v.push(Vec::new());
        }
        let (m, v) = (&mut self.m[slot], &mut self.v[slot]);
        if m.len() != param.len() {
            m.clear();
            m.resize(param.len(), 0.0);
            v.clear();
            v.resize(param.len(), 0.0);
        }
        let t = self.t.max(1);
        let bc1 = 1.0 - self.beta1.powi(t as i32);
        let bc2 = 1.0 - self.beta2.powi(t as i32);
        let (b1, b2, lr, eps) = (self.beta1, self.beta2, self.lr, self.eps);
        for (((w, &g), mi), vi) in
            param.iter_mut().zip(grad).zip(m.iter_mut()).zip(v.iter_mut())
        {
            *mi = b1 * *mi + (1.0 - b1) * g;
            *vi = b2 * *vi + (1.0 - b2) * g * g;
            let mhat = *mi / bc1;
            let vhat = *vi / bc2;
            *w -= lr * mhat / (vhat.sqrt() + eps);
        }
    }
}

/// Config-driven selection returns a boxed rule; forwarding keeps the
/// trainer generic-over-`O` path and the `Box<dyn Optimizer>` path
/// identical (the same pattern `FormPolicy` uses for boxed policies).
impl Optimizer for Box<dyn Optimizer> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn begin_step(&mut self) {
        (**self).begin_step();
    }

    fn update(&mut self, slot: usize, param: &mut [f32], grad: &[f32]) {
        (**self).update(slot, param, grad);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_trait_matches_the_closed_form() {
        let mut o = Sgd::new(0.1);
        let mut p = vec![1.0f32, -2.0];
        o.begin_step();
        o.update(0, &mut p, &[0.5, -0.5]);
        assert_eq!(p, vec![0.95, -1.95]);
    }

    #[test]
    fn adam_trait_matches_the_engine_enum_rule() {
        // the engine path's OptState::step_tensors implements the same
        // rule; both must produce identical trajectories
        use crate::train::{ModelOptimizer, OptState};
        let mut tr = Adam::new(0.05);
        let mut a = vec![vec![-4.0f32], vec![2.0f32]];
        let mut st = OptState::default();
        let mut b = a.clone();
        for _ in 0..50 {
            let ga: Vec<Vec<f32>> =
                a.iter().map(|p| vec![2.0 * p[0]]).collect();
            tr.begin_step();
            for (i, p) in a.iter_mut().enumerate() {
                tr.update(i, p, &ga[i]);
            }
            let gb: Vec<Vec<f32>> =
                b.iter().map(|p| vec![2.0 * p[0]]).collect();
            st.step_tensors(ModelOptimizer::adam(0.05), &mut b, &gb);
        }
        assert_eq!(a, b, "trait Adam diverged from the engine Adam");
        assert_eq!(tr.steps(), 50);
    }

    #[test]
    fn adam_moments_are_recycled_not_reallocated() {
        let mut o = Adam::new(0.01);
        let mut p = vec![0.0f32; 16];
        o.begin_step();
        o.update(0, &mut p, &[1.0; 16]);
        let cap_m = o.m[0].capacity();
        for _ in 0..20 {
            o.begin_step();
            o.update(0, &mut p, &[1.0; 16]);
        }
        assert_eq!(o.m[0].capacity(), cap_m, "moment buffer reallocated");
        assert_eq!(o.m.len(), 1);
    }

    #[test]
    fn boxed_optimizer_forwards() {
        let mut o: Box<dyn Optimizer> = Box::new(Sgd::new(0.5));
        assert_eq!(o.name(), "sgd");
        let mut p = vec![1.0f32];
        o.begin_step();
        o.update(0, &mut p, &[1.0]);
        assert_eq!(p, vec![0.5]);
    }
}
