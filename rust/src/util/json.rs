//! Minimal JSON parser + writer — enough for the artifact manifest, the
//! golden test vectors emitted by `python/compile/aot.py`, and the
//! machine-readable bench reports (`BENCH_<exp>.json`).
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null). Not performance-critical: the
//! manifest is parsed once at startup and goldens once per test.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a (possibly nested) numeric array into f32s, row-major.
    pub fn as_f32_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        fn rec(j: &Json, out: &mut Vec<f32>) {
            match j {
                Json::Num(n) => out.push(*n as f32),
                Json::Arr(a) => a.iter().for_each(|x| rec(x, out)),
                _ => {}
            }
        }
        rec(self, &mut out);
        out
    }

    pub fn as_usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default()
    }

    // -- builders + writer -------------------------------------------------

    /// Object from key/value pairs (keys end up in BTreeMap order).
    pub fn obj(pairs: impl IntoIterator<Item = (String, Json)>) -> Json {
        Json::Obj(pairs.into_iter().collect())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn text(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Serialize to compact JSON text. Non-finite numbers render as
    /// `null` (JSON has no NaN/Inf); integral numbers render without a
    /// fraction so the output round-trips through the parser bit-exact
    /// for the values the bench reports emit.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out);
        out
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn flattens_nested_numeric() {
        let j = Json::parse("[[1, 2], [3, 4]]").unwrap();
        assert_eq!(j.as_f32_flat(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn render_roundtrips_through_parse() {
        let j = Json::obj([
            ("exp".to_string(), Json::text("micro")),
            (
                "points".to_string(),
                Json::arr([
                    Json::obj([
                        ("threads".to_string(), Json::num(4.0)),
                        ("mean_s".to_string(), Json::num(0.001525)),
                        ("ok".to_string(), Json::Bool(true)),
                    ]),
                    Json::Null,
                ]),
            ),
            ("note".to_string(), Json::text("line\nbreak \"q\" \\ end")),
        ]);
        let text = j.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
        // integral numbers render without a fraction
        assert_eq!(Json::num(42.0).render(), "42");
        assert_eq!(Json::num(f64::NAN).render(), "null");
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }
}
