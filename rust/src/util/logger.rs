//! Tiny leveled logger (no env_logger offline). `CAVS_LOG=debug|info|warn`
//! controls verbosity; defaults to `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::LazyLock;
use std::time::Instant;

pub static START: LazyLock<Instant> = LazyLock::new(Instant::now);
static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=warn 2=info 3=debug

pub fn init() {
    LazyLock::force(&START);
    let lvl = match std::env::var("CAVS_LOG").as_deref() {
        Ok("off") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        _ => 2,
    };
    LEVEL.store(lvl, Ordering::Relaxed);
}

pub fn enabled(level: u8) -> bool {
    LEVEL.load(Ordering::Relaxed) >= level
}

pub fn log(level: u8, tag: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let t = START.elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::log(2, "info", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => {
        $crate::util::logger::log(1, "warn", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => {
        $crate::util::logger::log(3, "debug", format_args!($($arg)*))
    };
}
