//! Hand-rolled substrates: JSON parsing, RNG, logging, statistics and a
//! tiny property-testing driver.
//!
//! This environment has no network access to crates.io, so everything the
//! coordinator needs beyond the `xla` crate's own dependency tree is built
//! here from scratch (see DESIGN.md §3).

pub mod json;
pub mod logger;
pub mod propcheck;
pub mod rng;
pub mod stats;

/// Round `m` up to the next power-of-two bucket, capped at `max_bucket`.
/// Batching tasks larger than `max_bucket` are chunked by the scheduler.
pub fn bucket_for(m: usize, max_bucket: usize) -> usize {
    debug_assert!(m >= 1);
    let b = m.next_power_of_two();
    b.min(max_bucket)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rounds_up() {
        assert_eq!(bucket_for(1, 1024), 1);
        assert_eq!(bucket_for(3, 1024), 4);
        assert_eq!(bucket_for(4, 1024), 4);
        assert_eq!(bucket_for(5, 1024), 8);
        assert_eq!(bucket_for(1000, 1024), 1024);
        assert_eq!(bucket_for(5000, 1024), 1024);
    }
}
