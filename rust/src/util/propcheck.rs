//! Mini property-testing driver (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random
//! seeds; on failure it re-reports the failing seed so the case can be
//! replayed deterministically (`CAVS_PROP_SEED=<seed>` pins a single case,
//! `CAVS_PROP_CASES=<n>` scales effort).

use super::rng::Rng;

pub fn cases_from_env(default: usize) -> usize {
    std::env::var("CAVS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Run `prop` over `cases` seeded RNGs; panics with the failing seed.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut prop: F) {
    if let Ok(s) = std::env::var("CAVS_PROP_SEED") {
        let seed: u64 = s.parse().expect("CAVS_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        prop(&mut rng);
        return;
    }
    let cases = cases_from_env(cases);
    for case in 0..cases {
        // decorrelate consecutive seeds
        let seed = (case as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ 0xC0FFEE;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' FAILED at case {case} \
                 (replay with CAVS_PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        check("count", 17, |_rng| n += 1);
        assert_eq!(n, 17);
    }

    #[test]
    fn failure_reports_seed() {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check("boom", 5, |rng| {
                assert!(rng.f64() < 2.0); // never fails
                panic!("expected");
            });
        }));
        assert!(r.is_err());
    }
}
