//! Deterministic RNG (SplitMix64 + a Box–Muller normal) used by the
//! synthetic workload generators and parameter initialization.
//!
//! Every experiment in EXPERIMENTS.md is reproducible from the seed in its
//! config; nothing in the repo draws from OS entropy.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    cached_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), cached_normal: None }
    }

    /// SplitMix64 step.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(n) = self.cached_normal.take() {
            return n;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, scale: f32) -> f32 {
        (self.normal() as f32) * scale
    }

    /// Zipf-ish rank sampler over [0, n): p(k) ∝ 1/(k+1).
    /// Used by the synthetic PTB-like corpus (word frequencies in natural
    /// corpora are approximately Zipfian).
    pub fn zipf(&mut self, n: usize) -> usize {
        // inverse-CDF on the harmonic partial sums, computed incrementally;
        // cheap enough for corpus generation (n <= vocab).
        let hn: f64 = (1..=n).map(|k| 1.0 / k as f64).sum();
        let target = self.f64() * hn;
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / (k + 1) as f64;
            if acc >= target {
                return k;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            let k = r.range(3, 7);
            assert!((3..=7).contains(&k));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var =
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..5000 {
            counts[r.zipf(10)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[0] > counts[9] * 3);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
