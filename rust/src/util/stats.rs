//! Measurement statistics for the bench harness (no criterion offline):
//! warmup + repetition loops, mean/median/stddev/min, and human-readable
//! duration formatting.

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean_s: f64,
    /// p50 of the samples.
    pub median_s: f64,
    /// p95 of the samples (nearest-rank; equals the max for tiny n).
    pub p95_s: f64,
    /// p99 of the samples (nearest-rank; the serve tail-latency metric).
    pub p99_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// Nearest-rank percentile over an ascending-sorted sample list.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let idx = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        // total_cmp: a stray NaN sample (e.g. a zero-duration division
        // upstream) sorts to the end instead of panicking the reporter.
        sorted.sort_by(f64::total_cmp);
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean_s: mean,
            median_s: median,
            p95_s: percentile(&sorted, 95.0),
            p99_s: percentile(&sorted, 99.0),
            stddev_s: var.sqrt(),
            min_s: sorted[0],
            max_s: sorted[n - 1],
        }
    }
}

/// Run `f` for `warmup` unmeasured + `reps` measured repetitions.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from_samples(&samples)
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Fixed-bucket histogram for latency-style samples (seconds).
///
/// Bucket `i` counts samples with `x <= bounds[i]` (first matching bound,
/// ascending); the final slot counts overflow. Recording is O(log buckets)
/// with no allocation, so the serve loop can feed it per response.
/// NaN-safe like the percentiles above: NaN samples land in the overflow
/// slot instead of panicking the recorder.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// `bounds.len() + 1` slots; the last one is the overflow bucket.
    counts: Vec<u64>,
}

impl Histogram {
    /// `bounds` are ascending, finite upper edges (seconds).
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending: {bounds:?}"
        );
        assert!(
            bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be finite: {bounds:?}"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
        }
    }

    /// Default latency buckets: a 1-2-5 series from 10µs to 10s (19
    /// edges + overflow) — wide enough for host-cell microsecond batches
    /// and deadline-bound tail latencies alike.
    pub fn latency_default() -> Histogram {
        let mut bounds = Vec::with_capacity(19);
        let mut decade = 1e-5;
        while decade < 10.1 {
            for m in [1.0, 2.0, 5.0] {
                bounds.push(decade * m);
            }
            decade *= 10.0;
        }
        bounds.truncate(19); // ...5, 10 s; drop the trailing 20/50 s edges
        Histogram::new(&bounds)
    }

    pub fn record(&mut self, x: f64) {
        let i = if x.is_nan() {
            self.bounds.len() // overflow slot, not a panic
        } else {
            self.bounds.partition_point(|&b| b < x)
        };
        self.counts[i] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// One count per bucket; the last entry is the overflow bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    pub fn reset(&mut self) {
        self.counts.fill(0);
    }

    /// `(upper-edge label, count)` rows for the non-empty buckets —
    /// the human-readable rendering the serve report prints.
    pub fn nonzero(&self) -> Vec<(String, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let label = match self.bounds.get(i) {
                    Some(&b) => format!("<={}", fmt_duration(b)),
                    None => ">overflow".to_string(),
                };
                (label, c)
            })
            .collect()
    }
}

/// Accumulates wall-time into named phases; the instrument behind the
/// paper's "graph construction vs computation" and "memory ops vs
/// computation" breakdowns (Fig. 9, Tables 1–2).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    pub construction_s: f64,
    pub scheduling_s: f64,
    pub memory_s: f64,
    pub compute_s: f64,
    pub head_s: f64,
    pub optimizer_s: f64,
    pub other_s: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Construction,
    Scheduling,
    Memory,
    Compute,
    Head,
    Optimizer,
    Other,
}

impl PhaseTimer {
    pub fn add(&mut self, phase: Phase, d: Duration) {
        let s = d.as_secs_f64();
        match phase {
            Phase::Construction => self.construction_s += s,
            Phase::Scheduling => self.scheduling_s += s,
            Phase::Memory => self.memory_s += s,
            Phase::Compute => self.compute_s += s,
            Phase::Head => self.head_s += s,
            Phase::Optimizer => self.optimizer_s += s,
            Phase::Other => self.other_s += s,
        }
    }

    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(phase, t0.elapsed());
        r
    }

    pub fn total_s(&self) -> f64 {
        self.construction_s
            + self.scheduling_s
            + self.memory_s
            + self.compute_s
            + self.head_s
            + self.optimizer_s
            + self.other_s
    }

    pub fn merge(&mut self, o: &PhaseTimer) {
        self.construction_s += o.construction_s;
        self.scheduling_s += o.scheduling_s;
        self.memory_s += o.memory_s;
        self.compute_s += o.compute_s;
        self.head_s += o.head_s;
        self.optimizer_s += o.optimizer_s;
        self.other_s += o.other_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean_s - 2.5).abs() < 1e-12);
        assert!((s.median_s - 2.5).abs() < 1e-12);
        assert!((s.min_s - 1.0).abs() < 1e-12);
        assert!((s.max_s - 4.0).abs() < 1e-12);
        let expected_sd = (5.0f64 / 3.0).sqrt();
        assert!((s.stddev_s - expected_sd).abs() < 1e-12);
    }

    #[test]
    fn nan_sample_does_not_panic_and_sorts_last() {
        let s = Summary::from_samples(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert!((s.min_s - 1.0).abs() < 1e-12);
        assert!(s.max_s.is_nan(), "NaN must sort to the end, not panic");
        assert!((s.median_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn p95_is_nearest_rank() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::from_samples(&samples);
        assert!((s.p95_s - 95.0).abs() < 1e-12);
        // tiny n: p95 collapses to the max
        let s = Summary::from_samples(&[3.0, 1.0]);
        assert!((s.p95_s - 3.0).abs() < 1e-12);
        assert!((s.median_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn p99_is_nearest_rank() {
        let samples: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let s = Summary::from_samples(&samples);
        assert!((s.p99_s - 198.0).abs() < 1e-12);
        // tiny n: p99 collapses to the max, like p95
        let s = Summary::from_samples(&[3.0, 1.0]);
        assert!((s.p99_s - 3.0).abs() < 1e-12);
        // NaN-safe: NaN sorts last, percentiles of the finite prefix hold
        let s = Summary::from_samples(&[2.0, f64::NAN, 1.0]);
        assert!((s.median_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets_samples_at_first_covering_edge() {
        let mut h = Histogram::new(&[0.001, 0.01, 0.1]);
        h.record(0.0005); // <= 1ms
        h.record(0.001); // edge value lands in its own bucket
        h.record(0.05); // <= 100ms
        h.record(2.0); // overflow
        assert_eq!(h.counts(), &[2, 0, 1, 1]);
        assert_eq!(h.total(), 4);
        h.reset();
        assert_eq!(h.total(), 0);
    }

    #[test]
    fn histogram_is_nan_safe() {
        let mut h = Histogram::new(&[0.001, 0.01]);
        h.record(f64::NAN);
        h.record(-1.0); // nonsense sample still lands somewhere (bucket 0)
        assert_eq!(h.counts(), &[1, 0, 1]);
    }

    #[test]
    fn histogram_default_covers_latency_range() {
        let mut h = Histogram::latency_default();
        assert_eq!(h.bounds().len(), 19);
        assert!((h.bounds()[0] - 1e-5).abs() < 1e-18);
        assert!((h.bounds().last().unwrap() - 10.0).abs() < 1e-9);
        h.record(3e-5);
        h.record(0.5);
        h.record(100.0); // overflow
        assert_eq!(h.total(), 3);
        let nz = h.nonzero();
        assert_eq!(nz.len(), 3);
        assert!(nz.iter().any(|(l, _)| l == ">overflow"));
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn histogram_rejects_unsorted_bounds() {
        Histogram::new(&[0.01, 0.001]);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::default();
        t.add(Phase::Compute, Duration::from_millis(5));
        t.add(Phase::Compute, Duration::from_millis(5));
        t.add(Phase::Memory, Duration::from_millis(2));
        assert!((t.compute_s - 0.010).abs() < 1e-9);
        assert!((t.total_s() - 0.012).abs() < 1e-9);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(2.0).ends_with('s'));
        assert!(fmt_duration(0.002).ends_with("ms"));
        assert!(fmt_duration(2e-6).ends_with("µs"));
    }
}
