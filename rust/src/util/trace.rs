//! Chrome-trace (about://tracing / Perfetto) event recording for the
//! execution engine — the profiling tool behind the §Perf iteration log.
//!
//! Enable with `CAVS_TRACE=/path/out.json`; spans are recorded per
//! batching task / artifact execution / memory phase and written as a
//! Chrome `traceEvents` JSON array on flush.

use std::cell::RefCell;
use std::sync::LazyLock;
use std::time::Instant;

static EPOCH: LazyLock<Instant> = LazyLock::new(Instant::now);

#[derive(Debug, Clone)]
struct Event {
    name: String,
    cat: &'static str,
    ts_us: f64,
    dur_us: f64,
}

#[derive(Debug, Default)]
pub struct Trace {
    events: RefCell<Vec<Event>>,
    enabled: bool,
    path: Option<String>,
}

impl Trace {
    /// From the environment: enabled iff CAVS_TRACE is set.
    pub fn from_env() -> Trace {
        let path = std::env::var("CAVS_TRACE").ok();
        Trace { events: RefCell::new(Vec::new()), enabled: path.is_some(), path }
    }

    pub fn disabled() -> Trace {
        Trace::default()
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Begin a span; finish it by dropping the returned guard value into
    /// [`Trace::end`].
    pub fn begin(&self) -> Option<Instant> {
        self.enabled.then(Instant::now)
    }

    pub fn end(&self, start: Option<Instant>, cat: &'static str, name: impl Into<String>) {
        if let Some(t0) = start {
            let ts = t0.duration_since(*EPOCH).as_secs_f64() * 1e6;
            let dur = t0.elapsed().as_secs_f64() * 1e6;
            self.events.borrow_mut().push(Event {
                name: name.into(),
                cat,
                ts_us: ts,
                dur_us: dur,
            });
        }
    }

    pub fn len(&self) -> usize {
        self.events.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.borrow().is_empty()
    }

    /// Render the Chrome trace JSON.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        let events = self.events.borrow();
        for (i, e) in events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":{:?},\"cat\":\"{}\",\"ph\":\"X\",\"pid\":1,\
                 \"tid\":1,\"ts\":{:.1},\"dur\":{:.1}}}",
                e.name, e.cat, e.ts_us, e.dur_us
            ));
        }
        out.push_str("]}");
        out
    }

    /// Write to the CAVS_TRACE path (no-op when disabled).
    pub fn flush(&self) -> std::io::Result<()> {
        if let Some(p) = &self.path {
            std::fs::write(p, self.to_json())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let t = Trace::disabled();
        let g = t.begin();
        assert!(g.is_none());
        t.end(g, "compute", "task");
        assert!(t.is_empty());
    }

    #[test]
    fn events_render_as_chrome_json() {
        let t = Trace {
            events: RefCell::new(Vec::new()),
            enabled: true,
            path: None,
        };
        let g = t.begin();
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.end(g, "compute", "fwd:treelstm b=4");
        let g2 = t.begin();
        t.end(g2, "memory", "gather");
        assert_eq!(t.len(), 2);
        let j = t.to_json();
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.contains("fwd:treelstm b=4"));
        assert!(j.contains("\"ph\":\"X\""));
        // parses back with our own JSON parser
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("traceEvents").unwrap().as_arr().unwrap().len(),
            2
        );
    }
}
