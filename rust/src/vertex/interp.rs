//! The host `Program` interpreter: any validated vertex function runs —
//! forward *and* backward — with no per-cell code and no artifact set.
//!
//! [`ProgramCell`] wraps a [`Program`] plus host parameter tensors and
//! implements [`HostCell`](crate::exec::parallel::HostCell), so every
//! user-registered cell flows through [`HostFrontier`]
//! (crate::exec::parallel::HostFrontier), `run_host_frontier`, the host
//! training driver (`train::host`) and serve's `HostExec` exactly like
//! the hand-written reference cells.
//!
//! * **Forward** evaluates the op graph row-by-row over a preplanned
//!   *tape* (one scratch region per node, offsets fixed at construction)
//!   — zero allocation per row, and **bitwise identical** to the
//!   hand-written `HostLstm`/`HostTreeFc` cells: both sides perform the
//!   same f32 operations in the same order (property-tested).
//! * **Backward** is the §3.4 structural auto-differentiation: the tape
//!   is re-evaluated, then adjoints flow through the graph in reverse
//!   with per-op VJPs (MatMul, AddBias, Add, Mul, Sigmoid, Tanh,
//!   OneMinus, SliceCols, ConcatCols) and the message-passing dualities
//!   gather↔scatter-add and pull↔push: the scatter adjoint *seeds* the
//!   tape from `g_out`, gather adjoints leave through `gs`, and the pull
//!   adjoint leaves through `gx` (accumulated into the embedding table by
//!   the frontier executor).
//! * **Parameter gradients** accumulate per row through
//!   `acc_param_grads` — called sequentially by the frontier so the
//!   result is bitwise identical for every thread count.

use anyhow::{bail, Result};

use super::{OpKind, Program, ProgramMeta};
use crate::exec::parallel::HostCell;
use crate::util::rng::Rng;

/// The logistic function shared by the interpreter and the hand-written
/// host cells (one definition so equivalence is bitwise by construction).
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// A validated program bound to host parameter tensors: a generic
/// [`HostCell`] that executes F by interpretation.
pub struct ProgramCell {
    program: Program,
    meta: ProgramMeta,
    /// host parameter tensors, `program.params` order (row-major)
    params: Vec<Vec<f32>>,
    /// per-node tape offsets (prefix sums of node widths)
    off: Vec<usize>,
    /// total tape width per row
    tape_cols: usize,
    /// the node whose value scatter publishes (the state source)
    scatter_src: usize,
}

impl ProgramCell {
    /// Bind `program` to parameter tensors (validated against the
    /// declared [`ParamSpec`](super::ParamSpec) shapes).
    pub fn new(program: Program, params: Vec<Vec<f32>>) -> Result<ProgramCell> {
        let meta = program.validate()?;
        if params.len() != program.params.len() {
            bail!(
                "program '{}' declares {} parameters, got {}",
                program.name,
                program.params.len(),
                params.len()
            );
        }
        for (i, spec) in program.params.iter().enumerate() {
            if params[i].len() != spec.elements() {
                bail!(
                    "program '{}' parameter '{}' needs {} elements \
                     (shape {:?}), got {}",
                    program.name,
                    spec.name,
                    spec.elements(),
                    spec.shape,
                    params[i].len()
                );
            }
        }
        let mut off = Vec::with_capacity(program.nodes.len());
        let mut tape_cols = 0usize;
        for n in &program.nodes {
            off.push(tape_cols);
            tape_cols += n.cols;
        }
        let scatter_src = program
            .nodes
            .iter()
            .find(|n| matches!(n.kind, OpKind::Scatter))
            .map(|n| n.ins[0])
            .expect("validated program has a scatter");
        Ok(ProgramCell { program, meta, params, off, tape_cols, scatter_src })
    }

    /// Bind `program` to Gaussian-initialized parameters (the same init
    /// the `ParamSet` model store uses).
    pub fn random(program: Program, rng: &mut Rng, scale: f32) -> Result<ProgramCell> {
        let params = program
            .params
            .iter()
            .map(|p| (0..p.elements()).map(|_| rng.normal_f32(scale)).collect())
            .collect();
        ProgramCell::new(program, params)
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn meta(&self) -> &ProgramMeta {
        &self.meta
    }

    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Mutable access for optimizers (host training).
    pub fn params_mut(&mut self) -> &mut [Vec<f32>] {
        &mut self.params
    }

    /// Evaluate every node for one row into `tape` (length `tape_cols`).
    fn eval_tape(&self, x: &[f32], s: &[f32], tape: &mut [f32]) {
        let sc = self.meta.state_cols;
        for (i, node) in self.program.nodes.iter().enumerate() {
            if matches!(node.kind, OpKind::Scatter | OpKind::Push) {
                continue; // pure outputs: no tape value of their own
            }
            let (lo, hi) = tape.split_at_mut(self.off[i]);
            let out = &mut hi[..node.cols];
            match &node.kind {
                OpKind::Pull => out.copy_from_slice(x),
                OpKind::Gather { slot } => {
                    out.copy_from_slice(&s[slot * sc..(slot + 1) * sc])
                }
                OpKind::MatMul { param } => {
                    let k = self.program.nodes[node.ins[0]].cols;
                    let n = node.cols;
                    let a = &lo[self.off[node.ins[0]]..][..k];
                    let p = &self.params[*param];
                    // identical loop shape (k-outer, j-inner, skip-zero)
                    // to the hand-written host cells: bitwise equal sums
                    out.fill(0.0);
                    for (kk, &v) in a.iter().enumerate() {
                        if v != 0.0 {
                            let prow = &p[kk * n..(kk + 1) * n];
                            for (o, &w) in out.iter_mut().zip(prow) {
                                *o += v * w;
                            }
                        }
                    }
                }
                OpKind::AddBias { param } => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    let b = &self.params[*param];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = a[j] + b[j];
                    }
                }
                OpKind::Add => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    let b = &lo[self.off[node.ins[1]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = a[j] + b[j];
                    }
                }
                OpKind::Mul => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    let b = &lo[self.off[node.ins[1]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = a[j] * b[j];
                    }
                }
                OpKind::Sigmoid => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = sigmoid(a[j]);
                    }
                }
                OpKind::Tanh => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = a[j].tanh();
                    }
                }
                OpKind::OneMinus => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = 1.0 - a[j];
                    }
                }
                OpKind::SliceCols { start, len } => {
                    let a = &lo[self.off[node.ins[0]]..];
                    out.copy_from_slice(&a[*start..start + len]);
                }
                OpKind::ConcatCols => {
                    let mut col = 0usize;
                    for &src in &node.ins {
                        let w = self.program.nodes[src].cols;
                        out[col..col + w]
                            .copy_from_slice(&lo[self.off[src]..][..w]);
                        col += w;
                    }
                }
                OpKind::Scatter | OpKind::Push => unreachable!(),
            }
        }
    }

    /// Re-evaluate the tape and run the reverse adjoint sweep: seeds the
    /// scatter source with `g_out`, accumulates `gx` (pull adjoint) and
    /// the slot-concatenated `gs` (gather adjoints). `gx`/`gs` must
    /// arrive zeroed (the [`HostCell`] contract).
    fn backprop(
        &self,
        x: &[f32],
        s: &[f32],
        g_out: &[f32],
        gx: &mut [f32],
        gs: &mut [f32],
        tape: &mut [f32],
        adj: &mut [f32],
    ) {
        let sc = self.meta.state_cols;
        self.eval_tape(x, s, tape);
        adj.fill(0.0);
        {
            let seed = &mut adj[self.off[self.scatter_src]..][..sc];
            for (a, &g) in seed.iter_mut().zip(g_out) {
                *a += g;
            }
        }
        for (i, node) in self.program.nodes.iter().enumerate().rev() {
            match &node.kind {
                OpKind::Scatter | OpKind::Push => {} // seed / external sink
                OpKind::Pull => {
                    let g = &adj[self.off[i]..][..node.cols];
                    for (d, &v) in gx.iter_mut().zip(g) {
                        *d += v;
                    }
                }
                OpKind::Gather { slot } => {
                    let g = &adj[self.off[i]..][..node.cols];
                    let dst = &mut gs[slot * sc..(slot + 1) * sc];
                    for (d, &v) in dst.iter_mut().zip(g) {
                        *d += v;
                    }
                }
                OpKind::MatMul { param } => {
                    let k = self.program.nodes[node.ins[0]].cols;
                    let n = node.cols;
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let g = &ahi[..n];
                    let p = &self.params[*param];
                    let din = &mut alo[self.off[node.ins[0]]..][..k];
                    for (kk, d) in din.iter_mut().enumerate() {
                        let prow = &p[kk * n..(kk + 1) * n];
                        let mut acc = 0.0f32;
                        for (j, &w) in prow.iter().enumerate() {
                            acc += g[j] * w;
                        }
                        *d += acc;
                    }
                }
                OpKind::AddBias { .. } => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let g = &ahi[..node.cols];
                    let din = &mut alo[self.off[node.ins[0]]..][..node.cols];
                    for (d, &v) in din.iter_mut().zip(g) {
                        *d += v;
                    }
                }
                OpKind::Add => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let cols = node.cols;
                    // index loops: correct even if both inputs alias
                    for &src in &node.ins {
                        let o = self.off[src];
                        for j in 0..cols {
                            alo[o + j] += ahi[j];
                        }
                    }
                }
                OpKind::Mul => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let cols = node.cols;
                    let (ia, ib) = (node.ins[0], node.ins[1]);
                    let (oa, ob) = (self.off[ia], self.off[ib]);
                    for j in 0..cols {
                        let g = ahi[j];
                        let va = tape[oa + j];
                        let vb = tape[ob + j];
                        alo[oa + j] += g * vb;
                        alo[ob + j] += g * va;
                    }
                }
                OpKind::Sigmoid => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]];
                    for j in 0..node.cols {
                        let y = tape[self.off[i] + j];
                        alo[o_in + j] += ahi[j] * (y * (1.0 - y));
                    }
                }
                OpKind::Tanh => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]];
                    for j in 0..node.cols {
                        let y = tape[self.off[i] + j];
                        alo[o_in + j] += ahi[j] * (1.0 - y * y);
                    }
                }
                OpKind::OneMinus => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]];
                    for j in 0..node.cols {
                        alo[o_in + j] -= ahi[j];
                    }
                }
                OpKind::SliceCols { start, .. } => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]] + start;
                    for j in 0..node.cols {
                        alo[o_in + j] += ahi[j];
                    }
                }
                OpKind::ConcatCols => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let mut col = 0usize;
                    for &src in &node.ins {
                        let w = self.program.nodes[src].cols;
                        let o = self.off[src];
                        for j in 0..w {
                            alo[o + j] += ahi[col + j];
                        }
                        col += w;
                    }
                }
            }
        }
    }
}

impl HostCell for ProgramCell {
    fn arity(&self) -> usize {
        self.meta.arity
    }

    fn x_cols(&self) -> usize {
        self.meta.x_cols
    }

    fn state_cols(&self) -> usize {
        self.meta.state_cols
    }

    fn fwd_scratch_cols(&self) -> usize {
        self.tape_cols
    }

    fn bwd_scratch_cols(&self) -> usize {
        2 * self.tape_cols
    }

    fn forward(&self, x: &[f32], s: &[f32], out: &mut [f32], tmp: &mut [f32]) {
        let tape = &mut tmp[..self.tape_cols];
        self.eval_tape(x, s, tape);
        out.copy_from_slice(
            &tape[self.off[self.scatter_src]..][..self.meta.state_cols],
        );
    }

    fn backward(
        &self,
        x: &[f32],
        s: &[f32],
        g_out: &[f32],
        gx: &mut [f32],
        gs: &mut [f32],
        tmp: &mut [f32],
    ) {
        let (tape, adj) = tmp.split_at_mut(self.tape_cols);
        self.backprop(x, s, g_out, gx, gs, tape, &mut adj[..self.tape_cols]);
    }

    fn n_params(&self) -> usize {
        self.params.len()
    }

    fn param_len(&self, i: usize) -> usize {
        self.params[i].len()
    }

    fn pg_scratch_cols(&self) -> usize {
        2 * self.tape_cols + self.meta.x_cols + self.meta.arity * self.meta.state_cols
    }

    fn acc_param_grads(
        &self,
        x: &[f32],
        s: &[f32],
        g_out: &[f32],
        pg: &mut [Vec<f32>],
        tmp: &mut [f32],
    ) {
        let (tape, rest) = tmp.split_at_mut(self.tape_cols);
        let (adj, rest) = rest.split_at_mut(self.tape_cols);
        let (gx, gs) = rest.split_at_mut(self.meta.x_cols);
        let gs = &mut gs[..self.meta.arity * self.meta.state_cols];
        gx.fill(0.0);
        gs.fill(0.0);
        self.backprop(x, s, g_out, gx, gs, tape, adj);
        for (i, node) in self.program.nodes.iter().enumerate() {
            match &node.kind {
                OpKind::MatMul { param } => {
                    let k = self.program.nodes[node.ins[0]].cols;
                    let n = node.cols;
                    let a = &tape[self.off[node.ins[0]]..][..k];
                    let g = &adj[self.off[i]..][..n];
                    let dst = &mut pg[*param];
                    for (kk, &v) in a.iter().enumerate() {
                        if v != 0.0 {
                            let drow = &mut dst[kk * n..(kk + 1) * n];
                            for (d, &gj) in drow.iter_mut().zip(g) {
                                *d += v * gj;
                            }
                        }
                    }
                }
                OpKind::AddBias { param } => {
                    let g = &adj[self.off[i]..][..node.cols];
                    let dst = &mut pg[*param];
                    for (d, &gj) in dst.iter_mut().zip(g) {
                        *d += gj;
                    }
                }
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::programs;
    use super::*;

    fn cell(program: Program, seed: u64) -> ProgramCell {
        let mut rng = Rng::new(seed);
        ProgramCell::random(program, &mut rng, 0.2).unwrap()
    }

    #[test]
    fn rejects_mismatched_params() {
        let p = programs::treefc_program(4);
        assert!(ProgramCell::new(p.clone(), vec![]).is_err(), "missing params");
        let mut params: Vec<Vec<f32>> =
            p.params.iter().map(|s| vec![0.0; s.elements()]).collect();
        params[0].pop();
        assert!(ProgramCell::new(p, params).is_err(), "wrong element count");
    }

    #[test]
    fn forward_is_deterministic_and_stateful() {
        let h = 6;
        for program in [
            programs::lstm_program(h),
            programs::gru_program(h),
            programs::cstreelstm_program(h),
        ] {
            let c = cell(program, 3);
            let mut rng = Rng::new(9);
            let x: Vec<f32> = (0..c.x_cols()).map(|_| rng.normal_f32(0.5)).collect();
            let sc = c.state_cols() * c.arity();
            let s0 = vec![0.0f32; sc];
            let mut tmp = vec![0.0f32; c.fwd_scratch_cols()];
            let mut out1 = vec![0.0f32; c.state_cols()];
            c.forward(&x, &s0, &mut out1, &mut tmp);
            let mut out1b = vec![0.0f32; c.state_cols()];
            c.forward(&x, &s0, &mut out1b, &mut tmp);
            assert_eq!(out1, out1b, "{}: deterministic", c.program().name);
            assert!(out1.iter().all(|v| v.is_finite()));
            // feed the state back in (chains: slot 0)
            let mut s1 = vec![0.0f32; sc];
            s1[..c.state_cols()].copy_from_slice(&out1);
            let mut out2 = vec![0.0f32; c.state_cols()];
            c.forward(&x, &s1, &mut out2, &mut tmp);
            assert_ne!(out1, out2, "{}: state must matter", c.program().name);
        }
    }

    #[test]
    fn one_minus_forward_and_backward() {
        // a minimal program exercising OneMinus end to end:
        // state' = (1 - sigmoid(x + s)) — d/ds = -σ'(x+s)
        let h = 3;
        let mut p = Program::new("mini", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let s = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let a = p.node(OpKind::Add, vec![x, s], h);
        let sg = p.node(OpKind::Sigmoid, vec![a], h);
        let om = p.node(OpKind::OneMinus, vec![sg], h);
        p.node(OpKind::Scatter, vec![om], h);
        p.node(OpKind::Push, vec![om], h);
        let c = ProgramCell::new(p, vec![]).unwrap();
        let xv = [0.3f32, -0.7, 1.1];
        let sv = [0.1f32, 0.2, -0.4];
        let mut out = [0.0f32; 3];
        let mut tmp = vec![0.0f32; c.bwd_scratch_cols()];
        c.forward(&xv, &sv, &mut out, &mut tmp);
        for j in 0..3 {
            let want = 1.0 - sigmoid(xv[j] + sv[j]);
            assert!((out[j] - want).abs() < 1e-6);
        }
        let g = [1.0f32, 1.0, 1.0];
        let mut gx = [0.0f32; 3];
        let mut gs = [0.0f32; 3];
        c.backward(&xv, &sv, &g, &mut gx, &mut gs, &mut tmp);
        for j in 0..3 {
            let y = sigmoid(xv[j] + sv[j]);
            let want = -(y * (1.0 - y));
            assert!((gx[j] - want).abs() < 1e-5, "gx[{j}] {} vs {want}", gx[j]);
            assert_eq!(gx[j], gs[j], "x and s adjoints are symmetric here");
        }
    }

    #[test]
    fn shared_input_adjoints_accumulate() {
        // y = x * x (same node twice into Mul): dy/dx = 2x
        let h = 2;
        let mut p = Program::new("square", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let s = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let m = p.node(OpKind::Mul, vec![x, x], h);
        let a = p.node(OpKind::Add, vec![m, s], h);
        p.node(OpKind::Scatter, vec![a], h);
        p.node(OpKind::Push, vec![a], h);
        let c = ProgramCell::new(p, vec![]).unwrap();
        let xv = [1.5f32, -2.0];
        let sv = [0.0f32, 0.0];
        let g = [1.0f32, 1.0];
        let mut gx = [0.0f32; 2];
        let mut gs = [0.0f32; 2];
        let mut tmp = vec![0.0f32; c.bwd_scratch_cols()];
        c.backward(&xv, &sv, &g, &mut gx, &mut gs, &mut tmp);
        assert!((gx[0] - 3.0).abs() < 1e-6, "{}", gx[0]);
        assert!((gx[1] + 4.0).abs() < 1e-6, "{}", gx[1]);
        assert_eq!(gs, [1.0, 1.0]);
    }

    #[test]
    fn param_grads_match_finite_difference_probe() {
        // one quick FD spot-check here; the full 5-cell gradcheck lives in
        // rust/tests/gradcheck.rs
        let h = 4;
        let mut c = cell(programs::treefc_program(h), 11);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..h).map(|_| rng.normal_f32(0.5)).collect();
        let s: Vec<f32> = (0..2 * h).map(|_| rng.normal_f32(0.5)).collect();
        let w: Vec<f32> = (0..h).map(|_| rng.normal_f32(1.0)).collect();
        let loss = |c: &ProgramCell, tmp: &mut Vec<f32>| -> f64 {
            tmp.resize(c.fwd_scratch_cols().max(1), 0.0);
            let mut out = vec![0.0f32; h];
            c.forward(&x, &s, &mut out, tmp);
            out.iter().zip(&w).map(|(&o, &wj)| o as f64 * wj as f64).sum()
        };
        let mut tmp = vec![0.0f32; c.pg_scratch_cols()];
        let mut pg: Vec<Vec<f32>> =
            c.params().iter().map(|p| vec![0.0; p.len()]).collect();
        c.acc_param_grads(&x, &s, &w, &mut pg, &mut tmp);
        let mut ftmp = Vec::new();
        let eps = 1e-2f32;
        for (pi, idx) in [(0usize, 3usize), (3, 1)] {
            let analytic = pg[pi][idx] as f64;
            let orig = c.params()[pi][idx];
            c.params_mut()[pi][idx] = orig + eps;
            let lp = loss(&c, &mut ftmp);
            c.params_mut()[pi][idx] = orig - eps;
            let lm = loss(&c, &mut ftmp);
            c.params_mut()[pi][idx] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (fd - analytic).abs() <= 1e-3 * analytic.abs().max(1.0),
                "param {pi}[{idx}]: fd {fd} vs analytic {analytic}"
            );
        }
    }
}
