//! The host `Program` interpreter: any validated vertex function runs —
//! forward *and* backward — with no per-cell code and no artifact set.
//!
//! [`ProgramCell`] wraps a [`Program`] plus host parameter tensors and
//! implements [`HostCell`](crate::exec::parallel::HostCell), so every
//! user-registered cell flows through [`HostFrontier`]
//! (crate::exec::parallel::HostFrontier), `run_host_frontier`, the host
//! training driver (`train::host`) and serve's `HostExec` exactly like
//! the hand-written reference cells.
//!
//! * **Forward** evaluates the op graph row-by-row over a preplanned
//!   *tape* (one scratch region per node, offsets fixed at construction)
//!   — zero allocation per row, and **bitwise identical** to the
//!   hand-written `HostLstm`/`HostTreeFc` cells: both sides perform the
//!   same f32 operations in the same order (property-tested).
//! * **Backward** is the §3.4 structural auto-differentiation: the tape
//!   is re-evaluated, then adjoints flow through the graph in reverse
//!   with per-op VJPs (MatMul, AddBias, Add, Mul, Sigmoid, Tanh,
//!   OneMinus, SliceCols, ConcatCols) and the message-passing dualities
//!   gather↔scatter-add and pull↔push: the scatter adjoint *seeds* the
//!   tape from `g_out`, gather adjoints leave through `gs`, and the pull
//!   adjoint leaves through `gx` (accumulated into the embedding table by
//!   the frontier executor).
//! * **Parameter gradients** accumulate per row through
//!   `acc_param_grads` — called sequentially by the frontier so the
//!   result is bitwise identical for every thread count.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::opt::{OptProgram, OptStats, Step, WideGemm};
use super::{OpKind, OpNode, Program, ProgramMeta};
use crate::exec::kernels::{self, Kernels, MathMode, Variant};
use crate::exec::parallel::{HostCell, LevelCell};
use crate::obs;
use crate::util::rng::Rng;

/// The logistic function shared by the interpreter, the hand-written
/// host cells and the exact activation kernels (one definition — it
/// lives in `exec::kernels::act` — so equivalence is bitwise by
/// construction).
pub use crate::exec::kernels::act::sigmoid;

/// A validated program bound to host parameter tensors: a generic
/// [`HostCell`] that executes F by interpretation — either through the
/// reference per-node tape (the unoptimized baseline every equivalence
/// test compares against) or, when constructed with an
/// [`OptProgram`] plan, through the compiled schedule (views, wide
/// GEMMs, fused elementwise sweeps) with frontier-level batching via
/// [`LevelCell`]. Both paths are **bitwise identical** per output
/// element (see `vertex::opt`).
pub struct ProgramCell {
    program: Program,
    meta: ProgramMeta,
    /// host parameter tensors, `program.params` order (row-major)
    params: Vec<Vec<f32>>,
    /// per-node tape offsets (prefix sums of node widths)
    off: Vec<usize>,
    /// total tape width per row
    tape_cols: usize,
    /// the node whose value scatter publishes (the state source)
    scatter_src: usize,
    /// the compiled plan + bound merged weights (None = reference path)
    opt: Option<OptBound>,
}

/// An [`OptProgram`] bound to this cell's parameters: the
/// column-concatenated weight matrices of every merged GEMM plus their
/// SIMD-packed forms and the resolved kernel table, all built once at
/// bind time (and refreshed by [`ProgramCell::sync_opt`] after an
/// optimizer step mutates the underlying parameters).
struct OptBound {
    plan: Arc<OptProgram>,
    /// per-[`WideGemm`] concatenated `[k, n]` weights; empty for
    /// single-segment GEMMs (those read the declared parameter directly)
    wide_w: Vec<Vec<f32>>,
    /// per-[`WideGemm`] panel-packed weights for the SIMD forward GEMM
    /// ([`kernels::fill_panels`]), packed from `wide_w` or the declared
    /// parameter
    panels: Vec<Vec<f32>>,
    /// per-parameter `[n, k]` transposed weights for the SIMD MatMul
    /// data-gradient ([`kernels::fill_transpose`]); empty for parameters
    /// no MatMul node reads
    wt: Vec<Vec<f32>>,
    /// GEMM/din/activation kernels resolved at bind time by runtime CPU
    /// detection ([`Variant::detect`]) and the cell's [`MathMode`]
    kernels: Kernels,
}

/// The one Gaussian parameter-init stream (used by every constructor and
/// by `CellSpec::random_cell*`): the compiled-vs-reference equivalence
/// tests rely on both sides drawing the *identical* sequence, so this
/// must stay the single definition.
pub fn random_params(program: &Program, rng: &mut Rng, scale: f32) -> Vec<Vec<f32>> {
    program
        .params
        .iter()
        .map(|p| (0..p.elements()).map(|_| rng.normal_f32(scale)).collect())
        .collect()
}

fn bind_wide(plan: &OptProgram, params: &[Vec<f32>]) -> Vec<Vec<f32>> {
    plan.wide
        .iter()
        .map(|w| {
            if w.segs.len() < 2 {
                Vec::new()
            } else {
                let mut buf = vec![0.0f32; w.k * w.n];
                fill_wide(w, params, &mut buf);
                buf
            }
        })
        .collect()
}

/// Interleave the segment weight rows into the wide `[k, n]` matrix.
fn fill_wide(w: &WideGemm, params: &[Vec<f32>], buf: &mut [f32]) {
    let mut off = 0usize;
    for seg in &w.segs {
        let pm = &params[seg.param];
        for kk in 0..w.k {
            buf[kk * w.n + off..kk * w.n + off + seg.cols]
                .copy_from_slice(&pm[kk * seg.cols..(kk + 1) * seg.cols]);
        }
        off += seg.cols;
    }
}

/// The row-major weights a [`WideGemm`] multiplies by: the interleaved
/// wide matrix for merged GEMMs, the declared parameter otherwise.
fn wide_weights<'a>(w: &WideGemm, wide_w: &'a [f32], params: &'a [Vec<f32>]) -> &'a [f32] {
    if w.segs.len() >= 2 {
        wide_w
    } else {
        &params[w.segs[0].param]
    }
}

/// Panel-pack every wide GEMM's weights for the SIMD forward kernels.
fn bind_panels(plan: &OptProgram, params: &[Vec<f32>], wide_w: &[Vec<f32>]) -> Vec<Vec<f32>> {
    plan.wide
        .iter()
        .zip(wide_w)
        .map(|(w, ww)| {
            let mut buf = vec![0.0f32; kernels::panel_len(w.k, w.n)];
            kernels::fill_panels(wide_weights(w, ww, params), w.k, w.n, &mut buf);
            buf
        })
        .collect()
}

/// Transpose-pack every MatMul-read parameter for the SIMD din kernels.
fn bind_wt(plan: &OptProgram, params: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let mut wt: Vec<Vec<f32>> = params.iter().map(|_| Vec::new()).collect();
    for node in &plan.nodes {
        if let OpKind::MatMul { param } = node.kind {
            if wt[param].is_empty() {
                let k = plan.nodes[node.ins[0]].cols;
                let n = node.cols;
                let mut buf = vec![0.0f32; k * n];
                kernels::fill_transpose(&params[param], k, n, &mut buf);
                wt[param] = buf;
            }
        }
    }
    wt
}

/// Shared-read view of a tape region through its raw base pointer.
///
/// SAFETY: callers guarantee `[off, off + len)` is in bounds of the
/// buffer `base` was derived from and disjoint from every concurrently
/// live mutable region (the optimizer's layout invariant: a node's
/// storage never overlaps its inputs').
#[inline]
unsafe fn region<'a>(base: *const f32, off: usize, len: usize) -> &'a [f32] {
    // SAFETY: [inv:inbounds-view] caller guarantees the region is in
    // bounds of `base`'s buffer (the layout pass proves every plan
    // region is) and disjoint from live mutable regions.
    unsafe { std::slice::from_raw_parts(base.add(off), len) }
}

/// Mutable view of a tape region through its raw base pointer (same
/// safety contract as [`region`]).
#[inline]
unsafe fn region_mut<'a>(base: *mut f32, off: usize, len: usize) -> &'a mut [f32] {
    // SAFETY: [inv:inbounds-view] as [`region`], plus exclusivity: no
    // other live view overlaps ([inv:layout-disjoint]).
    unsafe { std::slice::from_raw_parts_mut(base.add(off), len) }
}

impl ProgramCell {
    /// Bind `program` to parameter tensors (validated against the
    /// declared [`ParamSpec`](super::ParamSpec) shapes).
    pub fn new(program: Program, params: Vec<Vec<f32>>) -> Result<ProgramCell> {
        let meta = program.validate()?;
        if params.len() != program.params.len() {
            bail!(
                "program '{}' declares {} parameters, got {}",
                program.name,
                program.params.len(),
                params.len()
            );
        }
        for (i, spec) in program.params.iter().enumerate() {
            if params[i].len() != spec.elements() {
                bail!(
                    "program '{}' parameter '{}' needs {} elements \
                     (shape {:?}), got {}",
                    program.name,
                    spec.name,
                    spec.elements(),
                    spec.shape,
                    params[i].len()
                );
            }
        }
        let mut off = Vec::with_capacity(program.nodes.len());
        let mut tape_cols = 0usize;
        for n in &program.nodes {
            off.push(tape_cols);
            tape_cols += n.cols;
        }
        let scatter_src = program
            .nodes
            .iter()
            .find(|n| matches!(n.kind, OpKind::Scatter))
            .map(|n| n.ins[0])
            .expect("validated program has a scatter");
        Ok(ProgramCell { program, meta, params, off, tape_cols, scatter_src, opt: None })
    }

    /// Bind `program` to `params` and compile it: runs
    /// [`Program::optimize`] and executes through the optimized schedule
    /// (the default host path — `CellSpec` uses the cached plan via
    /// [`ProgramCell::with_plan`] instead of re-running the passes).
    pub fn optimized(program: Program, params: Vec<Vec<f32>>) -> Result<ProgramCell> {
        let plan = Arc::new(program.optimize()?);
        ProgramCell::with_plan(program, plan, params)
    }

    /// Bind `program` + a precompiled plan (must come from this program's
    /// [`Program::optimize`]) to parameter tensors.
    pub fn with_plan(
        program: Program,
        plan: Arc<OptProgram>,
        params: Vec<Vec<f32>>,
    ) -> Result<ProgramCell> {
        debug_assert_eq!(plan.name, program.name, "plan/program mismatch");
        // bind-time layout soundness: a cached/deserialized plan is
        // re-verified before any executor trusts its addresses
        plan.verify().with_context(|| {
            format!("program '{}': bound plan failed layout verification", plan.name)
        })?;
        let mut c = ProgramCell::new(program, params)?;
        let wide_w = bind_wide(&plan, &c.params);
        let panels = bind_panels(&plan, &c.params, &wide_w);
        let wt = bind_wt(&plan, &c.params);
        let kernels = Kernels::resolve(MathMode::Exact);
        c.opt = Some(OptBound { plan, wide_w, panels, wt, kernels });
        Ok(c)
    }

    /// Bind `program` to Gaussian-initialized parameters (the same init
    /// the `ParamSet` model store uses). Reference (unoptimized) path.
    pub fn random(program: Program, rng: &mut Rng, scale: f32) -> Result<ProgramCell> {
        let params = random_params(&program, rng, scale);
        ProgramCell::new(program, params)
    }

    /// Gaussian-initialized **optimized** cell.
    pub fn random_optimized(
        program: Program,
        rng: &mut Rng,
        scale: f32,
    ) -> Result<ProgramCell> {
        let params = random_params(&program, rng, scale);
        ProgramCell::optimized(program, params)
    }

    /// Whether this cell executes through a compiled [`OptProgram`].
    pub fn is_optimized(&self) -> bool {
        self.opt.is_some()
    }

    /// Pass-pipeline statistics of the bound plan (None on the reference
    /// path).
    pub fn opt_stats(&self) -> Option<&OptStats> {
        self.opt.as_ref().map(|o| &o.plan.stats)
    }

    /// The bound plan (None on the reference path).
    pub fn opt_plan(&self) -> Option<&OptProgram> {
        self.opt.as_ref().map(|o| &*o.plan)
    }

    /// Re-interleave the merged GEMM weights — and refresh their SIMD
    /// packs — from the (possibly mutated) parameter tensors. Call after
    /// every optimizer step that writes through
    /// [`ProgramCell::params_mut`]; allocation-free (every pack refills
    /// its bind-time buffer in place), and a no-op on the reference path.
    pub fn sync_opt(&mut self) {
        let params = &self.params;
        if let Some(o) = &mut self.opt {
            let plan = Arc::clone(&o.plan);
            for (i, w) in plan.wide.iter().enumerate() {
                if w.segs.len() >= 2 {
                    fill_wide(w, params, &mut o.wide_w[i]);
                }
            }
            for (i, w) in plan.wide.iter().enumerate() {
                let src = wide_weights(w, &o.wide_w[i], params);
                kernels::fill_panels(src, w.k, w.n, &mut o.panels[i]);
            }
            for node in &plan.nodes {
                if let OpKind::MatMul { param } = node.kind {
                    if !o.wt[param].is_empty() {
                        let k = plan.nodes[node.ins[0]].cols;
                        let n = node.cols;
                        kernels::fill_transpose(&params[param], k, n, &mut o.wt[param]);
                    }
                }
            }
        }
    }

    /// Switch exact/fast math for the compiled path (the reference path
    /// is always exact). Re-resolves the kernel table in place —
    /// allocation-free; a no-op on the reference path.
    pub fn set_math(&mut self, math: MathMode) {
        if let Some(o) = &mut self.opt {
            o.kernels = Kernels::for_variant(o.kernels.variant, math);
        }
    }

    /// The compiled path's math mode (reference cells report `Exact`).
    pub fn math(&self) -> MathMode {
        self.opt.as_ref().map_or(MathMode::Exact, |o| o.kernels.math)
    }

    /// Force a specific kernel [`Variant`] through the dispatch table
    /// (dispatch tests and the scalar-vs-simd bench columns). Returns
    /// `false` — leaving the table untouched — if the CPU doesn't
    /// support the variant or this is a reference cell.
    pub fn set_kernel_variant(&mut self, variant: Variant) -> bool {
        match &mut self.opt {
            Some(o) if variant.available() => {
                o.kernels = Kernels::for_variant(variant, o.kernels.math);
                true
            }
            _ => false,
        }
    }

    /// The kernel variant the compiled path dispatches to (None on the
    /// reference path).
    pub fn kernel_variant(&self) -> Option<Variant> {
        self.opt.as_ref().map(|o| o.kernels.variant)
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn meta(&self) -> &ProgramMeta {
        &self.meta
    }

    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Mutable access for optimizers (host training).
    pub fn params_mut(&mut self) -> &mut [Vec<f32>] {
        &mut self.params
    }

    /// Evaluate every node for one row into `tape` (length `tape_cols`).
    fn eval_tape(&self, x: &[f32], s: &[f32], tape: &mut [f32]) {
        let sc = self.meta.state_cols;
        for (i, node) in self.program.nodes.iter().enumerate() {
            if matches!(node.kind, OpKind::Scatter | OpKind::Push) {
                continue; // pure outputs: no tape value of their own
            }
            let (lo, hi) = tape.split_at_mut(self.off[i]);
            let out = &mut hi[..node.cols];
            match &node.kind {
                OpKind::Pull => out.copy_from_slice(x),
                OpKind::Gather { slot } => {
                    out.copy_from_slice(&s[slot * sc..(slot + 1) * sc])
                }
                OpKind::MatMul { param } => {
                    let k = self.program.nodes[node.ins[0]].cols;
                    let n = node.cols;
                    let a = &lo[self.off[node.ins[0]]..][..k];
                    let p = &self.params[*param];
                    // identical loop shape (k-outer, j-inner) to the
                    // hand-written host cells: bitwise equal sums
                    out.fill(0.0);
                    for (kk, &v) in a.iter().enumerate() {
                        let prow = &p[kk * n..(kk + 1) * n];
                        for (o, &w) in out.iter_mut().zip(prow) {
                            *o += v * w;
                        }
                    }
                }
                OpKind::AddBias { param } => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    let b = &self.params[*param];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = a[j] + b[j];
                    }
                }
                OpKind::Add => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    let b = &lo[self.off[node.ins[1]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = a[j] + b[j];
                    }
                }
                OpKind::Mul => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    let b = &lo[self.off[node.ins[1]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = a[j] * b[j];
                    }
                }
                OpKind::Sigmoid => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = sigmoid(a[j]);
                    }
                }
                OpKind::Tanh => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = a[j].tanh();
                    }
                }
                OpKind::OneMinus => {
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = 1.0 - a[j];
                    }
                }
                OpKind::SliceCols { start, len } => {
                    let a = &lo[self.off[node.ins[0]]..];
                    out.copy_from_slice(&a[*start..start + len]);
                }
                OpKind::SoftmaxCols => {
                    // max-subtracted row softmax; this exact loop shape
                    // (max, exp+sum, scale by 1/sum) is the reference
                    // order the compiled RowOp step reproduces bitwise
                    let a = &lo[self.off[node.ins[0]]..][..node.cols];
                    let mut mx = f32::NEG_INFINITY;
                    for &v in a {
                        mx = mx.max(v);
                    }
                    let mut sum = 0.0f32;
                    for (j, o) in out.iter_mut().enumerate() {
                        let e = (a[j] - mx).exp();
                        *o = e;
                        sum += e;
                    }
                    let inv = 1.0 / sum;
                    for o in out.iter_mut() {
                        *o *= inv;
                    }
                }
                OpKind::Broadcast => {
                    let v = lo[self.off[node.ins[0]]];
                    out.fill(v);
                }
                OpKind::ConcatCols => {
                    let mut col = 0usize;
                    for &src in &node.ins {
                        let w = self.program.nodes[src].cols;
                        out[col..col + w]
                            .copy_from_slice(&lo[self.off[src]..][..w]);
                        col += w;
                    }
                }
                OpKind::Scatter | OpKind::Push => unreachable!(),
            }
        }
    }

    /// Re-evaluate the tape and run the reverse adjoint sweep: seeds the
    /// scatter source with `g_out`, accumulates `gx` (pull adjoint) and
    /// the slot-concatenated `gs` (gather adjoints). `gx`/`gs` must
    /// arrive zeroed (the [`HostCell`] contract).
    fn backprop(
        &self,
        x: &[f32],
        s: &[f32],
        g_out: &[f32],
        gx: &mut [f32],
        gs: &mut [f32],
        tape: &mut [f32],
        adj: &mut [f32],
    ) {
        let sc = self.meta.state_cols;
        self.eval_tape(x, s, tape);
        adj.fill(0.0);
        {
            let seed = &mut adj[self.off[self.scatter_src]..][..sc];
            for (a, &g) in seed.iter_mut().zip(g_out) {
                *a += g;
            }
        }
        for (i, node) in self.program.nodes.iter().enumerate().rev() {
            match &node.kind {
                OpKind::Scatter | OpKind::Push => {} // seed / external sink
                OpKind::Pull => {
                    let g = &adj[self.off[i]..][..node.cols];
                    for (d, &v) in gx.iter_mut().zip(g) {
                        *d += v;
                    }
                }
                OpKind::Gather { slot } => {
                    let g = &adj[self.off[i]..][..node.cols];
                    let dst = &mut gs[slot * sc..(slot + 1) * sc];
                    for (d, &v) in dst.iter_mut().zip(g) {
                        *d += v;
                    }
                }
                OpKind::MatMul { param } => {
                    let k = self.program.nodes[node.ins[0]].cols;
                    let n = node.cols;
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let g = &ahi[..n];
                    let p = &self.params[*param];
                    let din = &mut alo[self.off[node.ins[0]]..][..k];
                    for (kk, d) in din.iter_mut().enumerate() {
                        let prow = &p[kk * n..(kk + 1) * n];
                        let mut acc = 0.0f32;
                        for (j, &w) in prow.iter().enumerate() {
                            acc += g[j] * w;
                        }
                        *d += acc;
                    }
                }
                OpKind::AddBias { .. } => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let g = &ahi[..node.cols];
                    let din = &mut alo[self.off[node.ins[0]]..][..node.cols];
                    for (d, &v) in din.iter_mut().zip(g) {
                        *d += v;
                    }
                }
                OpKind::Add => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let cols = node.cols;
                    // index loops: correct even if both inputs alias
                    for &src in &node.ins {
                        let o = self.off[src];
                        for j in 0..cols {
                            alo[o + j] += ahi[j];
                        }
                    }
                }
                OpKind::Mul => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let cols = node.cols;
                    let (ia, ib) = (node.ins[0], node.ins[1]);
                    let (oa, ob) = (self.off[ia], self.off[ib]);
                    for j in 0..cols {
                        let g = ahi[j];
                        let va = tape[oa + j];
                        let vb = tape[ob + j];
                        alo[oa + j] += g * vb;
                        alo[ob + j] += g * va;
                    }
                }
                OpKind::Sigmoid => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]];
                    for j in 0..node.cols {
                        let y = tape[self.off[i] + j];
                        alo[o_in + j] += ahi[j] * (y * (1.0 - y));
                    }
                }
                OpKind::Tanh => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]];
                    for j in 0..node.cols {
                        let y = tape[self.off[i] + j];
                        alo[o_in + j] += ahi[j] * (1.0 - y * y);
                    }
                }
                OpKind::OneMinus => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]];
                    for j in 0..node.cols {
                        alo[o_in + j] -= ahi[j];
                    }
                }
                OpKind::SliceCols { start, .. } => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]] + start;
                    for j in 0..node.cols {
                        alo[o_in + j] += ahi[j];
                    }
                }
                OpKind::ConcatCols => {
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let mut col = 0usize;
                    for &src in &node.ins {
                        let w = self.program.nodes[src].cols;
                        let o = self.off[src];
                        for j in 0..w {
                            alo[o + j] += ahi[col + j];
                        }
                        col += w;
                    }
                }
                OpKind::SoftmaxCols => {
                    // ds_j = y_j * (g_j - Σ_k g_k y_k)
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]];
                    let y0 = self.off[i];
                    let mut dot = 0.0f32;
                    for j in 0..node.cols {
                        dot += ahi[j] * tape[y0 + j];
                    }
                    for j in 0..node.cols {
                        let y = tape[y0 + j];
                        alo[o_in + j] += y * (ahi[j] - dot);
                    }
                }
                OpKind::Broadcast => {
                    // the replicated scalar collects every column's adjoint
                    let (alo, ahi) = adj.split_at_mut(self.off[i]);
                    let o_in = self.off[node.ins[0]];
                    let mut acc = 0.0f32;
                    for j in 0..node.cols {
                        acc += ahi[j];
                    }
                    alo[o_in] += acc;
                }
            }
        }
    }

    // -----------------------------------------------------------------
    // Optimized execution (the compiled OptProgram schedule)
    // -----------------------------------------------------------------

    /// Execute one forward step for one row of the optimized tape. All
    /// tape access goes through the raw base pointer — regions are
    /// disjoint by the optimizer's layout invariant (a node's storage
    /// never overlaps its inputs'), and `tape` is not touched through the
    /// safe reference while the derived regions are live.
    fn exec_step_row(&self, o: &OptBound, step: &Step, x: &[f32], s: &[f32], tape: &mut [f32]) {
        let p = &*o.plan;
        let sc = p.meta.state_cols;
        let base = tape.as_mut_ptr();
        match step {
            Step::Pull { node } => {
                // SAFETY: [inv:layout-disjoint] the node's fresh/aliased
                // region is in bounds and no other region is live.
                let dst = unsafe { region_mut(base, p.addr[*node], p.meta.x_cols) };
                dst.copy_from_slice(x);
            }
            Step::Gather { node, slot } => {
                // SAFETY: [inv:layout-disjoint] as above.
                let dst = unsafe { region_mut(base, p.addr[*node], sc) };
                dst.copy_from_slice(&s[slot * sc..(slot + 1) * sc]);
            }
            Step::Concat { node } => {
                let n = &p.nodes[*node];
                let d0 = p.addr[*node];
                let mut off = 0usize;
                for &src in &n.ins {
                    let w = p.nodes[src].cols;
                    let sa = p.addr[src];
                    if sa != d0 + off {
                        // SAFETY: [inv:layout-disjoint] both ranges in
                        // bounds; `copy` tolerates overlap (none occurs —
                        // aliased inputs take the equal-address branch).
                        unsafe {
                            std::ptr::copy(
                                base.add(sa) as *const f32,
                                base.add(d0 + off),
                                w,
                            );
                        }
                    }
                    off += w;
                }
            }
            Step::Gemm { wide } => {
                let w = &p.wide[*wide];
                let weights = wide_weights(w, &o.wide_w[*wide], &self.params);
                let (src, dst) = (p.addr[w.input], p.addr[w.segs[0].node]);
                // one-row dispatch into the kernel table: the scalar
                // variant is the reference MatMul loop shape (k-outer,
                // j-inner), the SIMD exact variants reproduce its
                // per-element operation order — bitwise equal sums
                let stride = tape.len();
                (o.kernels.gemm)(tape, stride, 1, src, dst, w.k, w.n, weights, &o.panels[*wide]);
            }
            Step::Fused { group } => {
                let g = &p.fused[*group];
                let width = g.width;
                for &m in &g.nodes {
                    let node = &p.nodes[m];
                    // SAFETY: [inv:layout-disjoint] a member's storage is
                    // disjoint from every input's storage (layout
                    // invariant) — and so for every `region` read below.
                    let out = unsafe { region_mut(base, p.addr[m], width) };
                    match &node.kind {
                        OpKind::Add => {
                            // SAFETY: [inv:layout-disjoint] as above.
                            let a = unsafe { region(base as *const f32, p.addr[node.ins[0]], width) };
                            // SAFETY: [inv:layout-disjoint] as above.
                            let b = unsafe { region(base as *const f32, p.addr[node.ins[1]], width) };
                            for ((ov, &av), &bv) in out.iter_mut().zip(a).zip(b) {
                                *ov = av + bv;
                            }
                        }
                        OpKind::Mul => {
                            // SAFETY: [inv:layout-disjoint] as above.
                            let a = unsafe { region(base as *const f32, p.addr[node.ins[0]], width) };
                            // SAFETY: [inv:layout-disjoint] as above.
                            let b = unsafe { region(base as *const f32, p.addr[node.ins[1]], width) };
                            for ((ov, &av), &bv) in out.iter_mut().zip(a).zip(b) {
                                *ov = av * bv;
                            }
                        }
                        OpKind::AddBias { param } => {
                            // SAFETY: [inv:layout-disjoint] as above.
                            let a = unsafe { region(base as *const f32, p.addr[node.ins[0]], width) };
                            let bias = &self.params[*param];
                            for ((ov, &av), &bv) in out.iter_mut().zip(a).zip(bias) {
                                *ov = av + bv;
                            }
                        }
                        OpKind::Sigmoid => {
                            // SAFETY: [inv:layout-disjoint] as above.
                            let a = unsafe { region(base as *const f32, p.addr[node.ins[0]], width) };
                            (o.kernels.sigmoid)(out, a);
                        }
                        OpKind::Tanh => {
                            // SAFETY: [inv:layout-disjoint] as above.
                            let a = unsafe { region(base as *const f32, p.addr[node.ins[0]], width) };
                            (o.kernels.tanh)(out, a);
                        }
                        OpKind::OneMinus => {
                            // SAFETY: [inv:layout-disjoint] as above.
                            let a = unsafe { region(base as *const f32, p.addr[node.ins[0]], width) };
                            for (ov, &av) in out.iter_mut().zip(a) {
                                *ov = 1.0 - av;
                            }
                        }
                        _ => unreachable!("non-elementwise op in fused group"),
                    }
                }
            }
            Step::RowOp { node } => {
                let n = &p.nodes[*node];
                match &n.kind {
                    OpKind::SoftmaxCols => {
                        // SAFETY: [inv:layout-disjoint] a RowOp node is
                        // always Fresh (never a view), so its region is
                        // disjoint from its input's.
                        let a = unsafe { region(base as *const f32, p.addr[n.ins[0]], n.cols) };
                        // SAFETY: [inv:layout-disjoint] as above.
                        let out = unsafe { region_mut(base, p.addr[*node], n.cols) };
                        // identical loop shape to the reference
                        // `eval_tape` arm — bitwise-equal output
                        let mut mx = f32::NEG_INFINITY;
                        for &v in a.iter() {
                            mx = mx.max(v);
                        }
                        let mut sum = 0.0f32;
                        for (j, ov) in out.iter_mut().enumerate() {
                            let e = (a[j] - mx).exp();
                            *ov = e;
                            sum += e;
                        }
                        let inv = 1.0 / sum;
                        for ov in out.iter_mut() {
                            *ov *= inv;
                        }
                    }
                    OpKind::Broadcast => {
                        // SAFETY: [inv:layout-disjoint] as above.
                        let a = unsafe { region(base as *const f32, p.addr[n.ins[0]], 1) };
                        let v = a[0];
                        // SAFETY: [inv:layout-disjoint] as above.
                        let out = unsafe { region_mut(base, p.addr[*node], n.cols) };
                        out.fill(v);
                    }
                    _ => unreachable!("unsupported op in RowOp step"),
                }
            }
        }
    }

    /// Evaluate the whole optimized schedule for one row.
    fn eval_opt_row(&self, o: &OptBound, x: &[f32], s: &[f32], tape: &mut [f32]) {
        for step in &o.plan.steps {
            self.exec_step_row(o, step, x, s, tape);
        }
    }

    /// The §3.4 VJP of one node for one row over the optimized layout —
    /// the *original* per-node adjoint arithmetic (adjoint slots are
    /// never aliased), reading values through the view-resolved `addr`.
    /// Entirely safe indexed code: per-element local copies avoid any
    /// mutable/shared overlap in `adj`.
    fn vjp_node_row(
        &self,
        o: &OptBound,
        i: usize,
        node: &OpNode,
        tape: &[f32],
        adj: &mut [f32],
        gx: &mut [f32],
        gs: &mut [f32],
    ) {
        let p = &*o.plan;
        let sc = p.meta.state_cols;
        match &node.kind {
            OpKind::Scatter | OpKind::Push => {}
            OpKind::Pull => {
                let g = &adj[p.aoff[i]..][..node.cols];
                for (d, &v) in gx.iter_mut().zip(g) {
                    *d += v;
                }
            }
            OpKind::Gather { slot } => {
                let g = &adj[p.aoff[i]..][..node.cols];
                let dst = &mut gs[slot * sc..(slot + 1) * sc];
                for (d, &v) in dst.iter_mut().zip(g) {
                    *d += v;
                }
            }
            OpKind::MatMul { param } => {
                let k = p.nodes[node.ins[0]].cols;
                let n = node.cols;
                let g0 = p.aoff[i];
                let d0 = p.aoff[node.ins[0]];
                let pm = &self.params[*param];
                for kk in 0..k {
                    let prow = &pm[kk * n..(kk + 1) * n];
                    let mut acc = 0.0f32;
                    for (j, &wv) in prow.iter().enumerate() {
                        acc += adj[g0 + j] * wv;
                    }
                    adj[d0 + kk] += acc;
                }
            }
            OpKind::AddBias { .. } => {
                let g0 = p.aoff[i];
                let d0 = p.aoff[node.ins[0]];
                for j in 0..node.cols {
                    let g = adj[g0 + j];
                    adj[d0 + j] += g;
                }
            }
            OpKind::Add => {
                let g0 = p.aoff[i];
                // index loops: correct even if both inputs alias
                for &src in &node.ins {
                    let d0 = p.aoff[src];
                    for j in 0..node.cols {
                        let g = adj[g0 + j];
                        adj[d0 + j] += g;
                    }
                }
            }
            OpKind::Mul => {
                let g0 = p.aoff[i];
                let (ia, ib) = (node.ins[0], node.ins[1]);
                let (oa, ob) = (p.aoff[ia], p.aoff[ib]);
                let (va0, vb0) = (p.addr[ia], p.addr[ib]);
                for j in 0..node.cols {
                    let g = adj[g0 + j];
                    let va = tape[va0 + j];
                    let vb = tape[vb0 + j];
                    adj[oa + j] += g * vb;
                    adj[ob + j] += g * va;
                }
            }
            OpKind::Sigmoid => {
                let g0 = p.aoff[i];
                let d0 = p.aoff[node.ins[0]];
                let y0 = p.addr[i];
                for j in 0..node.cols {
                    let y = tape[y0 + j];
                    let g = adj[g0 + j];
                    adj[d0 + j] += g * (y * (1.0 - y));
                }
            }
            OpKind::Tanh => {
                let g0 = p.aoff[i];
                let d0 = p.aoff[node.ins[0]];
                let y0 = p.addr[i];
                for j in 0..node.cols {
                    let y = tape[y0 + j];
                    let g = adj[g0 + j];
                    adj[d0 + j] += g * (1.0 - y * y);
                }
            }
            OpKind::OneMinus => {
                let g0 = p.aoff[i];
                let d0 = p.aoff[node.ins[0]];
                for j in 0..node.cols {
                    let g = adj[g0 + j];
                    adj[d0 + j] -= g;
                }
            }
            OpKind::SliceCols { start, .. } => {
                let g0 = p.aoff[i];
                let d0 = p.aoff[node.ins[0]] + start;
                for j in 0..node.cols {
                    let g = adj[g0 + j];
                    adj[d0 + j] += g;
                }
            }
            OpKind::ConcatCols => {
                let g0 = p.aoff[i];
                let mut col = 0usize;
                for &src in &node.ins {
                    let w = p.nodes[src].cols;
                    let d0 = p.aoff[src];
                    for j in 0..w {
                        let g = adj[g0 + col + j];
                        adj[d0 + j] += g;
                    }
                    col += w;
                }
            }
            OpKind::SoftmaxCols => {
                // ds_j = y_j * (g_j - Σ_k g_k y_k)
                let g0 = p.aoff[i];
                let d0 = p.aoff[node.ins[0]];
                let y0 = p.addr[i];
                let mut dot = 0.0f32;
                for j in 0..node.cols {
                    dot += adj[g0 + j] * tape[y0 + j];
                }
                for j in 0..node.cols {
                    let y = tape[y0 + j];
                    let g = adj[g0 + j];
                    adj[d0 + j] += y * (g - dot);
                }
            }
            OpKind::Broadcast => {
                let g0 = p.aoff[i];
                let d0 = p.aoff[node.ins[0]];
                let mut acc = 0.0f32;
                for j in 0..node.cols {
                    acc += adj[g0 + j];
                }
                adj[d0] += acc;
            }
        }
    }

    /// Optimized-path backward for one row: recompute the tape, seed the
    /// scatter source's adjoint with `g_out`, run the reverse VJP sweep.
    fn backprop_opt_row(
        &self,
        o: &OptBound,
        x: &[f32],
        s: &[f32],
        g_out: &[f32],
        gx: &mut [f32],
        gs: &mut [f32],
        tape: &mut [f32],
        adj: &mut [f32],
    ) {
        let p = &*o.plan;
        self.eval_opt_row(o, x, s, tape);
        adj.fill(0.0);
        {
            let seed = &mut adj[p.aoff[p.scatter_src]..][..p.meta.state_cols];
            for (a, &g) in seed.iter_mut().zip(g_out) {
                *a += g;
            }
        }
        for (i, node) in p.nodes.iter().enumerate().rev() {
            self.vjp_node_row(o, i, node, tape, adj, gx, gs);
        }
    }

    /// Accumulate one row's parameter gradients from a completed
    /// tape/adjoint pair — forward node order, exactly the reference
    /// accumulation (merged GEMMs de-concatenate implicitly: each segment
    /// node writes its own declared `ParamSpec` tensor).
    fn acc_pg_row(&self, o: &OptBound, tape: &[f32], adj: &[f32], pg: &mut [Vec<f32>]) {
        let p = &*o.plan;
        for (i, node) in p.nodes.iter().enumerate() {
            match &node.kind {
                OpKind::MatMul { param } => {
                    let k = p.nodes[node.ins[0]].cols;
                    let n = node.cols;
                    let a = &tape[p.addr[node.ins[0]]..][..k];
                    let g = &adj[p.aoff[i]..][..n];
                    let dst = &mut pg[*param];
                    // the `v != 0.0` gate survives *only* here: gradient
                    // rows for zero activations are whole-row no-ops, and
                    // skipping the k·n row write still wins in the
                    // `bench --exp micro` fwd+bwd column — unlike the
                    // GEMM/din inner loops, where the same branch
                    // defeated vectorization for no measured gain and was
                    // removed (see `exec::kernels::scalar`)
                    for (kk, &v) in a.iter().enumerate() {
                        if v != 0.0 {
                            let drow = &mut dst[kk * n..(kk + 1) * n];
                            for (d, &gj) in drow.iter_mut().zip(g) {
                                *d += v * gj;
                            }
                        }
                    }
                }
                OpKind::AddBias { param } => {
                    let g = &adj[p.aoff[i]..][..node.cols];
                    let dst = &mut pg[*param];
                    for (d, &gj) in dst.iter_mut().zip(g) {
                        *d += gj;
                    }
                }
                _ => {}
            }
        }
    }

    /// Row-blocked level GEMM through the dispatch table: the selected
    /// kernel register-blocks [`kernels::GEMM_ROW_BLOCK`] vertex rows
    /// against the bind-time weight panels (SIMD variants) or streams
    /// each weight row once per block (scalar variant).
    fn gemm_rows(&self, o: &OptBound, wi: usize, tape: &mut [f32], tc: usize, m: usize) {
        let p = &*o.plan;
        let w = &p.wide[wi];
        let weights = wide_weights(w, &o.wide_w[wi], &self.params);
        let (src, dst) = (p.addr[w.input], p.addr[w.segs[0].node]);
        (o.kernels.gemm)(tape, tc, m, src, dst, w.k, w.n, weights, &o.panels[wi]);
    }

    /// Row-blocked level MatMul data-gradient through the dispatch
    /// table: `din[k] += Σ_j g[j]·W[k,j]` per row, with the SIMD variants
    /// reading the bind-time transposed pack. Per-element reduction order
    /// (j ascending) is the reference order in every variant.
    fn matmul_din_rows(
        &self,
        o: &OptBound,
        i: usize,
        node: &OpNode,
        adj: &mut [f32],
        lac: usize,
        m: usize,
    ) {
        let p = &*o.plan;
        let param = match node.kind {
            OpKind::MatMul { param } => param,
            _ => unreachable!(),
        };
        let k = p.nodes[node.ins[0]].cols;
        let n = node.cols;
        let (g0, d0) = (p.aoff[i], p.aoff[node.ins[0]]);
        (o.kernels.din)(adj, lac, m, g0, d0, k, n, &self.params[param], &o.wt[param]);
    }

    /// Level forward over a row range: op-outer, row-inner — each (fused)
    /// op sweeps all rows before the next op runs, GEMMs row-blocked.
    fn lvl_eval(&self, o: &OptBound, rows: &Range<usize>, x: &[f32], s: &[f32], tape: &mut [f32]) {
        let p = &*o.plan;
        let (xc, asc) = (p.meta.x_cols, p.meta.arity * p.meta.state_cols);
        let tc = p.tape_stride;
        let m = rows.len();
        for step in &p.steps {
            // Observability is attributed op-outer — one guard per batched
            // sweep, never per row (DESIGN.md §12): profiling classes
            // Gemm / Fused / Move, spans only for the compute sweeps.
            match step {
                Step::Gemm { wide } => {
                    let _prof = obs::profile::time(obs::OpClass::Gemm);
                    let _sp = obs::span("gemm", obs::Cat::Kernel)
                        .args(m as u32, p.wide[*wide].n as u32);
                    self.gemm_rows(o, *wide, tape, tc, m);
                }
                _ => {
                    let fused = matches!(step, Step::Fused { .. });
                    let _prof = obs::profile::time(if fused {
                        obs::OpClass::Fused
                    } else {
                        obs::OpClass::Move
                    });
                    let _sp = fused.then(|| {
                        obs::span("fused", obs::Cat::Kernel).args(m as u32, 0)
                    });
                    for r in 0..m {
                        let abs = rows.start + r;
                        self.exec_step_row(
                            o,
                            step,
                            &x[abs * xc..(abs + 1) * xc],
                            &s[abs * asc..(abs + 1) * asc],
                            &mut tape[r * tc..(r + 1) * tc],
                        );
                    }
                }
            }
        }
    }
}

impl HostCell for ProgramCell {
    fn arity(&self) -> usize {
        self.meta.arity
    }

    fn x_cols(&self) -> usize {
        self.meta.x_cols
    }

    fn state_cols(&self) -> usize {
        self.meta.state_cols
    }

    fn fwd_scratch_cols(&self) -> usize {
        match &self.opt {
            Some(o) => o.plan.tape_cols,
            None => self.tape_cols,
        }
    }

    fn bwd_scratch_cols(&self) -> usize {
        match &self.opt {
            Some(o) => o.plan.tape_cols + o.plan.adj_cols,
            None => 2 * self.tape_cols,
        }
    }

    fn forward(&self, x: &[f32], s: &[f32], out: &mut [f32], tmp: &mut [f32]) {
        match &self.opt {
            Some(o) => {
                let p = &*o.plan;
                let tape = &mut tmp[..p.tape_cols];
                self.eval_opt_row(o, x, s, tape);
                out.copy_from_slice(
                    &tape[p.addr[p.scatter_src]..][..p.meta.state_cols],
                );
            }
            None => {
                let tape = &mut tmp[..self.tape_cols];
                self.eval_tape(x, s, tape);
                out.copy_from_slice(
                    &tape[self.off[self.scatter_src]..][..self.meta.state_cols],
                );
            }
        }
    }

    fn backward(
        &self,
        x: &[f32],
        s: &[f32],
        g_out: &[f32],
        gx: &mut [f32],
        gs: &mut [f32],
        tmp: &mut [f32],
    ) {
        match &self.opt {
            Some(o) => {
                let (tape, adj) = tmp.split_at_mut(o.plan.tape_cols);
                self.backprop_opt_row(
                    o,
                    x,
                    s,
                    g_out,
                    gx,
                    gs,
                    tape,
                    &mut adj[..o.plan.adj_cols],
                );
            }
            None => {
                let (tape, adj) = tmp.split_at_mut(self.tape_cols);
                self.backprop(x, s, g_out, gx, gs, tape, &mut adj[..self.tape_cols]);
            }
        }
    }

    fn n_params(&self) -> usize {
        self.params.len()
    }

    fn param_len(&self, i: usize) -> usize {
        self.params[i].len()
    }

    fn pg_scratch_cols(&self) -> usize {
        let tapes = match &self.opt {
            Some(o) => o.plan.tape_cols + o.plan.adj_cols,
            None => 2 * self.tape_cols,
        };
        tapes + self.meta.x_cols + self.meta.arity * self.meta.state_cols
    }

    fn acc_param_grads(
        &self,
        x: &[f32],
        s: &[f32],
        g_out: &[f32],
        pg: &mut [Vec<f32>],
        tmp: &mut [f32],
    ) {
        if let Some(o) = &self.opt {
            let (tape, rest) = tmp.split_at_mut(o.plan.tape_cols);
            let (adj, rest) = rest.split_at_mut(o.plan.adj_cols);
            let (gx, gs) = rest.split_at_mut(self.meta.x_cols);
            let gs = &mut gs[..self.meta.arity * self.meta.state_cols];
            gx.fill(0.0);
            gs.fill(0.0);
            self.backprop_opt_row(o, x, s, g_out, gx, gs, tape, adj);
            self.acc_pg_row(o, tape, adj, pg);
            return;
        }
        let (tape, rest) = tmp.split_at_mut(self.tape_cols);
        let (adj, rest) = rest.split_at_mut(self.tape_cols);
        let (gx, gs) = rest.split_at_mut(self.meta.x_cols);
        let gs = &mut gs[..self.meta.arity * self.meta.state_cols];
        gx.fill(0.0);
        gs.fill(0.0);
        self.backprop(x, s, g_out, gx, gs, tape, adj);
        for (i, node) in self.program.nodes.iter().enumerate() {
            match &node.kind {
                OpKind::MatMul { param } => {
                    let k = self.program.nodes[node.ins[0]].cols;
                    let n = node.cols;
                    let a = &tape[self.off[node.ins[0]]..][..k];
                    let g = &adj[self.off[i]..][..n];
                    let dst = &mut pg[*param];
                    for (kk, &v) in a.iter().enumerate() {
                        if v != 0.0 {
                            let drow = &mut dst[kk * n..(kk + 1) * n];
                            for (d, &gj) in drow.iter_mut().zip(g) {
                                *d += v * gj;
                            }
                        }
                    }
                }
                OpKind::AddBias { param } => {
                    let g = &adj[self.off[i]..][..node.cols];
                    let dst = &mut pg[*param];
                    for (d, &gj) in dst.iter_mut().zip(g) {
                        *d += gj;
                    }
                }
                _ => {}
            }
        }
    }

    fn level(&self) -> Option<&dyn LevelCell> {
        self.opt.as_ref().map(|_| self as &dyn LevelCell)
    }
}

/// Frontier-level execution of the compiled schedule: `HostFrontier`
/// hands each worker shard a contiguous row range of the level's blocks
/// and the cell runs every (fused) op as a row-sharded batched
/// GEMM / fused elementwise sweep — op-outer, row-inner, with the GEMM
/// and MatMul-din loops dispatched to the SIMD microkernels in
/// `exec::kernels` (register-blocked rows against bind-time weight
/// packs). Rows are laid out at the plan's cache-line-padded
/// `tape_stride`/`adj_stride` pitch. In exact math the result is bitwise
/// identical to the per-row path (which is itself bitwise identical to
/// the reference interpreter).
impl LevelCell for ProgramCell {
    fn lvl_tape_cols(&self) -> usize {
        self.opt.as_ref().map_or(0, |o| o.plan.tape_stride)
    }

    fn lvl_adj_cols(&self) -> usize {
        self.opt.as_ref().map_or(0, |o| o.plan.adj_stride)
    }

    fn lvl_forward(
        &self,
        rows: Range<usize>,
        x: &[f32],
        s: &[f32],
        out: &mut [f32],
        tape: &mut [f32],
    ) {
        let o = self.opt.as_ref().expect("level execution needs a compiled plan");
        let p = &*o.plan;
        let (sc, tc) = (p.meta.state_cols, p.tape_stride);
        let m = rows.len();
        self.lvl_eval(o, &rows, x, s, tape);
        let src = p.addr[p.scatter_src];
        for r in 0..m {
            out[r * sc..(r + 1) * sc].copy_from_slice(&tape[r * tc + src..][..sc]);
        }
    }

    fn lvl_backward(
        &self,
        rows: Range<usize>,
        x: &[f32],
        s: &[f32],
        g_out: &[f32],
        gx: &mut [f32],
        gs: &mut [f32],
        tape: &mut [f32],
        adj: &mut [f32],
    ) {
        let o = self.opt.as_ref().expect("level execution needs a compiled plan");
        let p = &*o.plan;
        let sc = p.meta.state_cols;
        let (xc, asc) = (p.meta.x_cols, p.meta.arity * sc);
        let (tc, lac) = (p.tape_stride, p.adj_stride);
        let m = rows.len();
        // recompute the forward tape for these rows (blocked GEMMs)
        self.lvl_eval(o, &rows, x, s, tape);
        // seed every row's adjoint with its g_out
        for r in 0..m {
            let abs = rows.start + r;
            let arow = &mut adj[r * lac..(r + 1) * lac];
            arow.fill(0.0);
            let seed = &mut arow[p.aoff[p.scatter_src]..][..sc];
            for (a, &g) in seed.iter_mut().zip(&g_out[abs * sc..(abs + 1) * sc]) {
                *a += g;
            }
        }
        // reverse VJP sweep, op-outer: MatMul data-grads row-blocked,
        // everything else per row — per-row arithmetic is the reference's
        for (i, node) in p.nodes.iter().enumerate().rev() {
            if matches!(node.kind, OpKind::MatMul { .. }) {
                let _prof = obs::profile::time(obs::OpClass::Din);
                let _sp = obs::span("din", obs::Cat::Kernel)
                    .args(m as u32, node.cols as u32);
                self.matmul_din_rows(o, i, node, adj, lac, m);
                continue;
            }
            let _prof = obs::profile::time(obs::OpClass::Vjp);
            for r in 0..m {
                self.vjp_node_row(
                    o,
                    i,
                    node,
                    &tape[r * tc..(r + 1) * tc],
                    &mut adj[r * lac..(r + 1) * lac],
                    &mut gx[r * xc..(r + 1) * xc],
                    &mut gs[r * asc..(r + 1) * asc],
                );
            }
        }
    }

    fn lvl_param_grads(&self, rows: usize, tape: &[f32], adj: &[f32], pg: &mut [Vec<f32>]) {
        let o = self.opt.as_ref().expect("level execution needs a compiled plan");
        let (tc, lac) = (o.plan.tape_stride, o.plan.adj_stride);
        let _prof = obs::profile::time(obs::OpClass::Pgrad);
        for r in 0..rows {
            self.acc_pg_row(o, &tape[r * tc..(r + 1) * tc], &adj[r * lac..(r + 1) * lac], pg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::programs;
    use super::*;

    fn cell(program: Program, seed: u64) -> ProgramCell {
        let mut rng = Rng::new(seed);
        ProgramCell::random(program, &mut rng, 0.2).unwrap()
    }

    #[test]
    fn rejects_mismatched_params() {
        let p = programs::treefc_program(4);
        assert!(ProgramCell::new(p.clone(), vec![]).is_err(), "missing params");
        let mut params: Vec<Vec<f32>> =
            p.params.iter().map(|s| vec![0.0; s.elements()]).collect();
        params[0].pop();
        assert!(ProgramCell::new(p, params).is_err(), "wrong element count");
    }

    #[test]
    fn forward_is_deterministic_and_stateful() {
        let h = 6;
        for program in [
            programs::lstm_program(h),
            programs::gru_program(h),
            programs::cstreelstm_program(h),
        ] {
            let c = cell(program, 3);
            let mut rng = Rng::new(9);
            let x: Vec<f32> = (0..c.x_cols()).map(|_| rng.normal_f32(0.5)).collect();
            let sc = c.state_cols() * c.arity();
            let s0 = vec![0.0f32; sc];
            let mut tmp = vec![0.0f32; c.fwd_scratch_cols()];
            let mut out1 = vec![0.0f32; c.state_cols()];
            c.forward(&x, &s0, &mut out1, &mut tmp);
            let mut out1b = vec![0.0f32; c.state_cols()];
            c.forward(&x, &s0, &mut out1b, &mut tmp);
            assert_eq!(out1, out1b, "{}: deterministic", c.program().name);
            assert!(out1.iter().all(|v| v.is_finite()));
            // feed the state back in (chains: slot 0)
            let mut s1 = vec![0.0f32; sc];
            s1[..c.state_cols()].copy_from_slice(&out1);
            let mut out2 = vec![0.0f32; c.state_cols()];
            c.forward(&x, &s1, &mut out2, &mut tmp);
            assert_ne!(out1, out2, "{}: state must matter", c.program().name);
        }
    }

    #[test]
    fn one_minus_forward_and_backward() {
        // a minimal program exercising OneMinus end to end:
        // state' = (1 - sigmoid(x + s)) — d/ds = -σ'(x+s)
        let h = 3;
        let mut p = Program::new("mini", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let s = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let a = p.node(OpKind::Add, vec![x, s], h);
        let sg = p.node(OpKind::Sigmoid, vec![a], h);
        let om = p.node(OpKind::OneMinus, vec![sg], h);
        p.node(OpKind::Scatter, vec![om], h);
        p.node(OpKind::Push, vec![om], h);
        let c = ProgramCell::new(p, vec![]).unwrap();
        let xv = [0.3f32, -0.7, 1.1];
        let sv = [0.1f32, 0.2, -0.4];
        let mut out = [0.0f32; 3];
        let mut tmp = vec![0.0f32; c.bwd_scratch_cols()];
        c.forward(&xv, &sv, &mut out, &mut tmp);
        for j in 0..3 {
            let want = 1.0 - sigmoid(xv[j] + sv[j]);
            assert!((out[j] - want).abs() < 1e-6);
        }
        let g = [1.0f32, 1.0, 1.0];
        let mut gx = [0.0f32; 3];
        let mut gs = [0.0f32; 3];
        c.backward(&xv, &sv, &g, &mut gx, &mut gs, &mut tmp);
        for j in 0..3 {
            let y = sigmoid(xv[j] + sv[j]);
            let want = -(y * (1.0 - y));
            assert!((gx[j] - want).abs() < 1e-5, "gx[{j}] {} vs {want}", gx[j]);
            assert_eq!(gx[j], gs[j], "x and s adjoints are symmetric here");
        }
    }

    #[test]
    fn shared_input_adjoints_accumulate() {
        // y = x * x (same node twice into Mul): dy/dx = 2x
        let h = 2;
        let mut p = Program::new("square", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let s = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let m = p.node(OpKind::Mul, vec![x, x], h);
        let a = p.node(OpKind::Add, vec![m, s], h);
        p.node(OpKind::Scatter, vec![a], h);
        p.node(OpKind::Push, vec![a], h);
        let c = ProgramCell::new(p, vec![]).unwrap();
        let xv = [1.5f32, -2.0];
        let sv = [0.0f32, 0.0];
        let g = [1.0f32, 1.0];
        let mut gx = [0.0f32; 2];
        let mut gs = [0.0f32; 2];
        let mut tmp = vec![0.0f32; c.bwd_scratch_cols()];
        c.backward(&xv, &sv, &g, &mut gx, &mut gs, &mut tmp);
        assert!((gx[0] - 3.0).abs() < 1e-6, "{}", gx[0]);
        assert!((gx[1] + 4.0).abs() < 1e-6, "{}", gx[1]);
        assert_eq!(gs, [1.0, 1.0]);
    }

    #[test]
    fn param_grads_match_finite_difference_probe() {
        // one quick FD spot-check here; the full 5-cell gradcheck lives in
        // rust/tests/gradcheck.rs
        let h = 4;
        let mut c = cell(programs::treefc_program(h), 11);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..h).map(|_| rng.normal_f32(0.5)).collect();
        let s: Vec<f32> = (0..2 * h).map(|_| rng.normal_f32(0.5)).collect();
        let w: Vec<f32> = (0..h).map(|_| rng.normal_f32(1.0)).collect();
        let loss = |c: &ProgramCell, tmp: &mut Vec<f32>| -> f64 {
            tmp.resize(c.fwd_scratch_cols().max(1), 0.0);
            let mut out = vec![0.0f32; h];
            c.forward(&x, &s, &mut out, tmp);
            out.iter().zip(&w).map(|(&o, &wj)| o as f64 * wj as f64).sum()
        };
        let mut tmp = vec![0.0f32; c.pg_scratch_cols()];
        let mut pg: Vec<Vec<f32>> =
            c.params().iter().map(|p| vec![0.0; p.len()]).collect();
        c.acc_param_grads(&x, &s, &w, &mut pg, &mut tmp);
        let mut ftmp = Vec::new();
        let eps = 1e-2f32;
        for (pi, idx) in [(0usize, 3usize), (3, 1)] {
            let analytic = pg[pi][idx] as f64;
            let orig = c.params()[pi][idx];
            c.params_mut()[pi][idx] = orig + eps;
            let lp = loss(&c, &mut ftmp);
            c.params_mut()[pi][idx] = orig - eps;
            let lm = loss(&c, &mut ftmp);
            c.params_mut()[pi][idx] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (fd - analytic).abs() <= 1e-3 * analytic.abs().max(1.0),
                "param {pi}[{idx}]: fd {fd} vs analytic {analytic}"
            );
        }
    }
}
