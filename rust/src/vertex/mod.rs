//! The vertex function `F` as a small static dataflow graph (paper §3.1,
//! Fig. 7), plus the §3.5 static analyses that the execution engine
//! consumes:
//!
//! * **fusion detection** — union-find over chains of element-wise
//!   operators; each fuse-able group can be replaced by one fused kernel
//!   (in this repo: the whole-cell fused Pallas artifact),
//! * **eager/lazy classification** (Proposition 2) — eager ops do not
//!   depend on `gather` (they can run before child results arrive, on a
//!   second stream); lazy ops do not feed `scatter` (their execution can
//!   be deferred past all batching tasks),
//! * structural **auto-differentiation** metadata (gather↔scatter,
//!   pull↔push duality, §3.4).
//!
//! `Program` is the **single authoritative description of F**: everything
//! the rest of the system needs — gather arity, state width, the slice of
//! the state that heads read, gate-preactivation width, the named
//! parameter shapes — is *derived* from the op graph by
//! [`Program::validate`] (which also rejects malformed programs with a
//! proper error instead of a debug assertion). The [`registry`] maps cell
//! names to program builders (builtin + user-registered), and
//! [`interp::ProgramCell`] executes any validated program on the host —
//! forward and the §3.4 structural backward — with no per-cell code.
//!
//! The default engine executes F through the fused whole-cell artifact;
//! the `fusion=false` ablation interprets this op graph node-by-node, one
//! PJRT execution per operator (one "kernel launch" per op, like the
//! paper's unfused GPU baseline).

pub mod interp;
pub mod opt;
pub mod programs;
pub mod registry;

use std::collections::BTreeSet;

use anyhow::{bail, Result};

/// Op kinds. `param` indexes into the program's [`ParamSpec`] list.
/// (`Ord`/`Hash` exist so the optimizer's CSE pass can key on
/// structural op equality.)
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpKind {
    /// gather(slot): child state -> dense task block
    Gather { slot: usize },
    /// pull(): external input (embedding row / upstream connector)
    Pull,
    /// scatter: publish this vertex's state for parents
    Scatter,
    /// push: publish to the external connector (heads read it)
    Push,
    /// x @ P (P is a model parameter, row-major `[in_cols, out_cols]`)
    MatMul { param: usize },
    /// x + b (broadcast bias parameter, `[cols]`)
    AddBias { param: usize },
    Add,
    Mul,
    Sigmoid,
    Tanh,
    /// y = 1 - x (elementwise; the GRU update-gate complement)
    OneMinus,
    /// take columns [start, start+len) of the input (host memcpy)
    SliceCols { start: usize, len: usize },
    /// concatenate inputs along columns (host memcpy)
    ConcatCols,
    /// row-local softmax over the input's columns (attention weights);
    /// NOT elementwise — each output column reads every input column, so
    /// it can never join a fused group
    SoftmaxCols,
    /// replicate a 1-column input across the node's columns (broadcast an
    /// attention weight over a memory row); row-local like SoftmaxCols
    Broadcast,
}

impl OpKind {
    /// Element-wise ops are the fusion candidates (§3.5: "+, -, ×, ÷,
    /// tanh, sigmoid").
    pub fn is_elementwise(&self) -> bool {
        matches!(
            self,
            OpKind::Add | OpKind::Mul | OpKind::Sigmoid | OpKind::Tanh | OpKind::OneMinus
        )
    }

    /// The §3.4 adjoint duality for the four message-passing primitives.
    pub fn adjoint_primitive(&self) -> Option<OpKind> {
        match self {
            OpKind::Gather { .. } => Some(OpKind::Scatter),
            OpKind::Scatter => Some(OpKind::Gather { slot: 0 }),
            OpKind::Pull => Some(OpKind::Push),
            OpKind::Push => Some(OpKind::Pull),
            _ => None,
        }
    }

    /// Inputs this op consumes: `Some(n)` for a fixed count, `None` for
    /// "one or more" (ConcatCols).
    fn input_arity(&self) -> Option<usize> {
        match self {
            OpKind::Gather { .. } | OpKind::Pull => Some(0),
            OpKind::Scatter
            | OpKind::Push
            | OpKind::MatMul { .. }
            | OpKind::AddBias { .. }
            | OpKind::Sigmoid
            | OpKind::Tanh
            | OpKind::OneMinus
            | OpKind::SliceCols { .. }
            | OpKind::SoftmaxCols
            | OpKind::Broadcast => Some(1),
            OpKind::Add | OpKind::Mul => Some(2),
            OpKind::ConcatCols => None,
        }
    }
}

/// A named model parameter the program references by index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct OpNode {
    pub kind: OpKind,
    /// input node ids
    pub ins: Vec<usize>,
    /// output width (columns per vertex)
    pub cols: usize,
}

/// The vertex function as a DAG of ops. Node ids are topological by
/// construction (builders append in dependency order); [`Program::validate`]
/// rejects anything else with a proper error.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub nodes: Vec<OpNode>,
    /// number of child slots (1 chain, 2 binary tree)
    pub n_children: usize,
    /// columns of the scattered state
    pub state_cols: usize,
    /// named parameters, referenced by `MatMul { param }` / `AddBias { param }`
    pub params: Vec<ParamSpec>,
}

/// Everything the system derives from a validated program: the metadata
/// that used to be hand-duplicated on the closed `Cell` enum.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProgramMeta {
    /// child slots gathered per vertex
    pub arity: usize,
    /// columns of the scattered state
    pub state_cols: usize,
    /// columns of the pull input `x`
    pub x_cols: usize,
    /// (offset, len) of the state slice heads read (the push source
    /// located inside the scattered state)
    pub h_off: usize,
    pub h_len: usize,
    /// gate-preactivation columns (Σ AddBias widths) — what bwd_data
    /// emits for lazy parameter gradients
    pub gates_cols: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Analysis {
    /// fuse-able groups (node ids), each of size >= 2
    pub fusion_groups: Vec<Vec<usize>>,
    /// eager nodes: gather is NOT an ancestor (can run on stream 2)
    pub eager: BTreeSet<usize>,
    /// lazy nodes: scatter is NOT a descendant (deferrable)
    pub lazy: BTreeSet<usize>,
}

impl Program {
    /// Start an empty program. Append parameters with [`Program::param`]
    /// and ops with [`Program::node`], then check it with
    /// [`Program::validate`].
    pub fn new(name: &str, n_children: usize, state_cols: usize) -> Program {
        Program {
            name: name.to_string(),
            nodes: Vec::new(),
            n_children,
            state_cols,
            params: Vec::new(),
        }
    }

    /// Declare a named parameter; returns its index for `MatMul`/`AddBias`.
    pub fn param(&mut self, name: &str, shape: &[usize]) -> usize {
        self.params.push(ParamSpec { name: name.to_string(), shape: shape.to_vec() });
        self.params.len() - 1
    }

    /// Append an op node. No checking happens here — malformed graphs are
    /// reported by [`Program::validate`] (called at CellSpec registration
    /// and manifest load), not by assertions.
    pub fn node(&mut self, kind: OpKind, ins: Vec<usize>, cols: usize) -> usize {
        self.nodes.push(OpNode { kind, ins, cols });
        self.nodes.len() - 1
    }

    /// Check the program is a well-formed vertex function and derive its
    /// metadata. Errors on:
    ///
    /// * forward references / cycles / dangling inputs,
    /// * input-count or column-width mismatches on any op,
    /// * parameter indices out of range or shapes inconsistent with use,
    /// * missing or duplicate `pull` / `scatter` / `push`,
    /// * gather slots that do not cover `0..n_children` exactly once,
    /// * unconsumed intermediate nodes,
    /// * a push source that is not locatable inside the scattered state
    ///   (heads could not read it).
    pub fn validate(&self) -> Result<ProgramMeta> {
        let name = &self.name;
        if self.nodes.is_empty() {
            bail!("program '{name}': no ops");
        }
        if self.n_children == 0 {
            bail!("program '{name}': n_children must be >= 1");
        }
        if self.state_cols == 0 {
            bail!("program '{name}': state_cols must be >= 1");
        }
        for (i, p) in self.params.iter().enumerate() {
            if p.name.is_empty() {
                bail!("program '{name}': parameter {i} has an empty name");
            }
            if p.shape.is_empty() || p.shape.contains(&0) {
                bail!(
                    "program '{name}': parameter '{}' has invalid shape {:?}",
                    p.name,
                    p.shape
                );
            }
            if self.params[..i].iter().any(|q| q.name == p.name) {
                bail!("program '{name}': duplicate parameter name '{}'", p.name);
            }
        }

        // topology: every input must reference an earlier node; since ids
        // are appended in order, a forward (or self) reference is exactly
        // what a cycle or a dangling input looks like here.
        for (i, n) in self.nodes.iter().enumerate() {
            for &j in &n.ins {
                if j >= i {
                    bail!(
                        "program '{name}': node {i} ({:?}) references node {j} \
                         which is not defined before it (cycle or dangling input)",
                        n.kind
                    );
                }
            }
            if n.cols == 0 {
                bail!("program '{name}': node {i} ({:?}) has zero columns", n.kind);
            }
            if let Some(want) = n.kind.input_arity() {
                if n.ins.len() != want {
                    bail!(
                        "program '{name}': node {i} ({:?}) takes {want} input(s), \
                         got {}",
                        n.kind,
                        n.ins.len()
                    );
                }
            } else if n.ins.is_empty() {
                bail!("program '{name}': node {i} (ConcatCols) has no inputs");
            }
        }

        // per-op width rules
        let cols_of = |j: usize| self.nodes[j].cols;
        for (i, n) in self.nodes.iter().enumerate() {
            match &n.kind {
                OpKind::MatMul { param } => {
                    let p = self.params.get(*param).ok_or_else(|| {
                        anyhow::anyhow!(
                            "program '{name}': node {i} references parameter \
                             {param}, but only {} are declared",
                            self.params.len()
                        )
                    })?;
                    let k = cols_of(n.ins[0]);
                    if p.shape != [k, n.cols] {
                        bail!(
                            "program '{name}': node {i} MatMul needs parameter \
                             '{}' of shape [{k}, {}], declared {:?}",
                            p.name,
                            n.cols,
                            p.shape
                        );
                    }
                }
                OpKind::AddBias { param } => {
                    let p = self.params.get(*param).ok_or_else(|| {
                        anyhow::anyhow!(
                            "program '{name}': node {i} references parameter \
                             {param}, but only {} are declared",
                            self.params.len()
                        )
                    })?;
                    if cols_of(n.ins[0]) != n.cols {
                        bail!(
                            "program '{name}': node {i} AddBias input is \
                             {} cols, node is {} cols",
                            cols_of(n.ins[0]),
                            n.cols
                        );
                    }
                    if p.shape != [n.cols] {
                        bail!(
                            "program '{name}': node {i} AddBias needs parameter \
                             '{}' of shape [{}], declared {:?}",
                            p.name,
                            n.cols,
                            p.shape
                        );
                    }
                }
                OpKind::Add | OpKind::Mul => {
                    for &j in &n.ins {
                        if cols_of(j) != n.cols {
                            bail!(
                                "program '{name}': node {i} ({:?}) mixes widths \
                                 {} and {}",
                                n.kind,
                                cols_of(j),
                                n.cols
                            );
                        }
                    }
                }
                OpKind::Sigmoid
                | OpKind::Tanh
                | OpKind::OneMinus
                | OpKind::Push
                | OpKind::SoftmaxCols => {
                    if cols_of(n.ins[0]) != n.cols {
                        bail!(
                            "program '{name}': node {i} ({:?}) input is {} cols, \
                             node is {} cols",
                            n.kind,
                            cols_of(n.ins[0]),
                            n.cols
                        );
                    }
                }
                OpKind::Broadcast => {
                    if cols_of(n.ins[0]) != 1 {
                        bail!(
                            "program '{name}': node {i} Broadcast input must be \
                             1 col, got {}",
                            cols_of(n.ins[0])
                        );
                    }
                }
                OpKind::SliceCols { start, len } => {
                    if *len == 0 || n.cols != *len || start + len > cols_of(n.ins[0]) {
                        bail!(
                            "program '{name}': node {i} SliceCols [{start}, \
                             {start}+{len}) of a {}-col input (node is {} cols)",
                            cols_of(n.ins[0]),
                            n.cols
                        );
                    }
                }
                OpKind::ConcatCols => {
                    let total: usize = n.ins.iter().map(|&j| cols_of(j)).sum();
                    if total != n.cols {
                        bail!(
                            "program '{name}': node {i} ConcatCols inputs sum to \
                             {total} cols, node is {} cols",
                            n.cols
                        );
                    }
                }
                OpKind::Scatter => {
                    if cols_of(n.ins[0]) != self.state_cols || n.cols != self.state_cols
                    {
                        bail!(
                            "program '{name}': scatter is {} cols (input {}), \
                             state_cols is {}",
                            n.cols,
                            cols_of(n.ins[0]),
                            self.state_cols
                        );
                    }
                }
                OpKind::Gather { .. } => {
                    if n.cols != self.state_cols {
                        bail!(
                            "program '{name}': node {i} gathers {} cols, \
                             state_cols is {}",
                            n.cols,
                            self.state_cols
                        );
                    }
                }
                OpKind::Pull => {}
            }
        }

        // the message-passing skeleton: exactly one pull, one scatter, one
        // push; gather slots cover 0..n_children exactly once each
        let pulls = self.ids_of(|k| matches!(k, OpKind::Pull));
        let scatters = self.ids_of(|k| matches!(k, OpKind::Scatter));
        let pushes = self.ids_of(|k| matches!(k, OpKind::Push));
        match pulls.len() {
            0 => bail!("program '{name}': no pull (external input)"),
            1 => {}
            n => bail!("program '{name}': {n} pull ops (exactly one allowed)"),
        }
        match scatters.len() {
            0 => bail!("program '{name}': no scatter (state is never published)"),
            1 => {}
            n => bail!("program '{name}': {n} scatter ops (exactly one allowed)"),
        }
        match pushes.len() {
            0 => bail!("program '{name}': no push (heads have nothing to read)"),
            1 => {}
            n => bail!("program '{name}': {n} push ops (exactly one allowed)"),
        }
        let mut slots_seen = vec![0usize; self.n_children];
        for (i, n) in self.nodes.iter().enumerate() {
            if let OpKind::Gather { slot } = n.kind {
                if slot >= self.n_children {
                    bail!(
                        "program '{name}': node {i} gathers slot {slot}, but \
                         n_children is {}",
                        self.n_children
                    );
                }
                slots_seen[slot] += 1;
            }
        }
        for (slot, &count) in slots_seen.iter().enumerate() {
            match count {
                0 => bail!("program '{name}': child slot {slot} is never gathered"),
                1 => {}
                n => bail!("program '{name}': child slot {slot} gathered {n} times"),
            }
        }

        // every non-sink node must be consumed by someone (dead ops are a
        // bug in the cell definition, not an optimization opportunity)
        let mut used = vec![false; self.nodes.len()];
        for n in &self.nodes {
            for &j in &n.ins {
                used[j] = true;
            }
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if !used[i] && !matches!(n.kind, OpKind::Scatter | OpKind::Push) {
                bail!(
                    "program '{name}': node {i} ({:?}) is computed but never \
                     consumed",
                    n.kind
                );
            }
        }

        // derive the head slice: where the push source lives inside the
        // scattered state (so heads can gather it from the state buffer)
        let s_in = self.nodes[scatters[0]].ins[0];
        let p_in = self.nodes[pushes[0]].ins[0];
        let (h_off, h_len) = if p_in == s_in {
            (0, self.nodes[s_in].cols)
        } else if matches!(self.nodes[s_in].kind, OpKind::ConcatCols)
            && self.nodes[s_in].ins.contains(&p_in)
        {
            let mut off = 0;
            let mut found = None;
            for &j in &self.nodes[s_in].ins {
                if j == p_in {
                    found = Some(off);
                    break;
                }
                off += self.nodes[j].cols;
            }
            (found.unwrap(), self.nodes[p_in].cols)
        } else {
            bail!(
                "program '{name}': the push source (node {p_in}) is not part of \
                 the scattered state (node {s_in}) — heads could not read it"
            );
        };

        Ok(ProgramMeta {
            arity: self.n_children,
            state_cols: self.state_cols,
            x_cols: self.nodes[pulls[0]].cols,
            h_off,
            h_len,
            gates_cols: self.gates_cols(),
        })
    }

    /// Gate-preactivation columns: the sum of all `AddBias` widths — the
    /// per-vertex block `cell_bwd_data` artifacts emit for the lazy
    /// parameter-gradient pass.
    pub fn gates_cols(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::AddBias { .. }))
            .map(|n| n.cols)
            .sum()
    }

    fn reachable_from(&self, sources: &[usize]) -> Vec<bool> {
        // nodes are topologically ordered, one forward sweep suffices
        let mut reach = vec![false; self.nodes.len()];
        for &s in sources {
            reach[s] = true;
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if !reach[i] && n.ins.iter().any(|&j| reach[j]) {
                reach[i] = true;
            }
        }
        reach
    }

    fn reaches(&self, targets: &[usize]) -> Vec<bool> {
        // reverse reachability: does node i reach any target?
        let mut reach = vec![false; self.nodes.len()];
        for &t in targets {
            reach[t] = true;
        }
        for i in (0..self.nodes.len()).rev() {
            if reach[i] {
                for &j in &self.nodes[i].ins {
                    reach[j] = true;
                }
            }
        }
        reach
    }

    fn ids_of(&self, pred: impl Fn(&OpKind) -> bool) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| pred(&n.kind))
            .map(|(i, _)| i)
            .collect()
    }

    /// Run the §3.5 static analyses (assumes a validated program).
    pub fn analyze(&self) -> Analysis {
        let gathers = self.ids_of(|k| matches!(k, OpKind::Gather { .. }));
        let scatters = self.ids_of(|k| matches!(k, OpKind::Scatter));

        // ---- Proposition 2 ----
        let below_gather = self.reachable_from(&gathers);
        let feeds_scatter = self.reaches(&scatters);
        let mut eager = BTreeSet::new();
        let mut lazy = BTreeSet::new();
        for i in 0..self.nodes.len() {
            let is_gather = gathers.contains(&i);
            let is_scatter = scatters.contains(&i);
            if !below_gather[i] && !is_gather {
                eager.insert(i);
            }
            if !feeds_scatter[i] && !is_scatter {
                lazy.insert(i);
            }
        }

        // ---- fusion: union-find over element-wise adjacency ----
        let mut parent: Vec<usize> = (0..self.nodes.len()).collect();
        fn find(p: &mut Vec<usize>, x: usize) -> usize {
            if p[x] != x {
                let r = find(p, p[x]);
                p[x] = r;
            }
            p[x]
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.kind.is_elementwise() {
                continue;
            }
            for &j in &n.ins {
                if self.nodes[j].kind.is_elementwise() {
                    let (a, b) = (find(&mut parent, i), find(&mut parent, j));
                    if a != b {
                        parent[a] = b;
                    }
                }
            }
        }
        let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
            Default::default();
        for i in 0..self.nodes.len() {
            if self.nodes[i].kind.is_elementwise() {
                groups.entry(find(&mut parent, i)).or_default().push(i);
            }
        }
        let fusion_groups: Vec<Vec<usize>> =
            groups.into_values().filter(|g| g.len() >= 2).collect();

        Analysis { fusion_groups, eager, lazy }
    }

    /// Number of PJRT executions ("kernel launches") the unfused
    /// interpretation needs per task: every non-memory op.
    pub fn launches_unfused(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| {
                matches!(
                    n.kind,
                    OpKind::MatMul { .. }
                        | OpKind::AddBias { .. }
                        | OpKind::Add
                        | OpKind::Mul
                        | OpKind::Sigmoid
                        | OpKind::Tanh
                        | OpKind::OneMinus
                        | OpKind::SoftmaxCols
                        | OpKind::Broadcast
                )
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::programs::*;
    use super::*;

    #[test]
    fn lstm_program_analysis_matches_fig7() {
        let p = lstm_program(8);
        let a = p.analyze();
        // pull and the x-side matmul are eager (don't depend on gather)
        let pulls = p.ids_of(|k| matches!(k, OpKind::Pull));
        assert!(pulls.iter().all(|i| a.eager.contains(i)));
        let xmms: Vec<usize> = p
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                matches!(n.kind, OpKind::MatMul { .. })
                    && n.ins.iter().any(|&j| pulls.contains(&j))
            })
            .map(|(i, _)| i)
            .collect();
        assert!(!xmms.is_empty());
        assert!(xmms.iter().all(|i| a.eager.contains(i)));
        // push is lazy
        let pushes = p.ids_of(|k| matches!(k, OpKind::Push));
        assert!(pushes.iter().all(|i| a.lazy.contains(i)));
        // the h-side matmul is NOT eager (consumes gathered state)
        let gathers = p.ids_of(|k| matches!(k, OpKind::Gather { .. }));
        assert!(!gathers.is_empty());
        // there is at least one sizeable fuse-able element-wise group
        // (the gate nonlinearity + cell-update chain of Fig. 7)
        assert!(!a.fusion_groups.is_empty());
        assert!(a.fusion_groups.iter().any(|g| g.len() >= 4));
    }

    #[test]
    fn scatter_never_lazy_gather_never_eager() {
        for p in [lstm_program(4), treelstm_program(4), treefc_program(4)] {
            let a = p.analyze();
            for (i, n) in p.nodes.iter().enumerate() {
                if matches!(n.kind, OpKind::Scatter) {
                    assert!(!a.lazy.contains(&i));
                }
                if matches!(n.kind, OpKind::Gather { .. }) {
                    assert!(!a.eager.contains(&i));
                }
            }
        }
    }

    #[test]
    fn adjoint_duality() {
        assert_eq!(
            OpKind::Gather { slot: 1 }.adjoint_primitive(),
            Some(OpKind::Scatter)
        );
        assert_eq!(OpKind::Pull.adjoint_primitive(), Some(OpKind::Push));
        assert_eq!(OpKind::Push.adjoint_primitive(), Some(OpKind::Pull));
        assert_eq!(OpKind::Add.adjoint_primitive(), None);
    }

    #[test]
    fn fusion_groups_are_elementwise_only() {
        for p in [lstm_program(8), treelstm_program(8), gru_program(8)] {
            let a = p.analyze();
            for g in &a.fusion_groups {
                for &i in g {
                    assert!(p.nodes[i].kind.is_elementwise());
                }
            }
        }
    }

    #[test]
    fn launch_counts() {
        // fused cell = 1 launch; unfused LSTM needs ~a dozen
        assert!(lstm_program(8).launches_unfused() >= 10);
        assert!(treelstm_program(8).launches_unfused() >= 15);
        assert!(treefc_program(8).launches_unfused() >= 5);
    }

    // ---- Program::validate: every malformed-program class -------------

    #[test]
    fn validate_accepts_all_shipped_programs() {
        for h in [1usize, 4, 8, 32] {
            for p in [
                lstm_program(h),
                treelstm_program(h),
                treefc_program(h),
                gru_program(h),
                cstreelstm_program(h),
            ] {
                let meta = p.validate().unwrap_or_else(|e| {
                    panic!("{} h={h} failed validation: {e:#}", p.name)
                });
                assert_eq!(meta.arity, p.n_children);
                assert_eq!(meta.state_cols, p.state_cols);
                assert_eq!(meta.x_cols, h);
                assert!(meta.h_off + meta.h_len <= meta.state_cols);
                assert!(meta.gates_cols > 0);
            }
        }
    }

    #[test]
    fn validate_derives_the_enum_metadata() {
        // the derived values must match what the old Cell enum hard-coded
        let h = 16;
        let m = lstm_program(h).validate().unwrap();
        assert_eq!((m.arity, m.state_cols, m.gates_cols), (1, 2 * h, 4 * h));
        assert_eq!((m.h_off, m.h_len), (h, h));
        let m = treelstm_program(h).validate().unwrap();
        assert_eq!((m.arity, m.state_cols, m.gates_cols), (2, 2 * h, 5 * h));
        assert_eq!((m.h_off, m.h_len), (h, h));
        let m = treefc_program(h).validate().unwrap();
        assert_eq!((m.arity, m.state_cols, m.gates_cols), (2, h, h));
        assert_eq!((m.h_off, m.h_len), (0, h));
        let m = gru_program(h).validate().unwrap();
        assert_eq!((m.arity, m.state_cols, m.gates_cols), (1, h, 3 * h));
        assert_eq!((m.h_off, m.h_len), (0, h));
    }

    #[test]
    fn validate_rejects_forward_reference_cycle() {
        let mut p = Program::new("bad", 1, 2);
        let x = p.node(OpKind::Pull, vec![], 2);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], 2);
        // node 2 references node 3 (not yet defined): a cycle/dangling input
        let a = p.node(OpKind::Add, vec![x, 3], 2);
        let b = p.node(OpKind::Add, vec![a, g], 2);
        p.node(OpKind::Scatter, vec![b], 2);
        p.node(OpKind::Push, vec![b], 2);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("cycle or dangling input"), "{e}");
    }

    #[test]
    fn validate_rejects_width_mismatch() {
        let mut p = Program::new("bad", 1, 4);
        let x = p.node(OpKind::Pull, vec![], 4);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], 4);
        let t = p.node(OpKind::SliceCols { start: 0, len: 2 }, vec![g], 2);
        let a = p.node(OpKind::Add, vec![x, t], 4); // 4 + 2: mismatch
        p.node(OpKind::Scatter, vec![a], 4);
        p.node(OpKind::Push, vec![a], 4);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("mixes widths"), "{e}");
    }

    #[test]
    fn validate_rejects_missing_scatter() {
        let mut p = Program::new("bad", 1, 2);
        let x = p.node(OpKind::Pull, vec![], 2);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], 2);
        let a = p.node(OpKind::Add, vec![x, g], 2);
        p.node(OpKind::Push, vec![a], 2);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("no scatter"), "{e}");
    }

    #[test]
    fn validate_rejects_duplicate_pull() {
        let mut p = Program::new("bad", 1, 2);
        let x1 = p.node(OpKind::Pull, vec![], 2);
        let x2 = p.node(OpKind::Pull, vec![], 2);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], 2);
        let a = p.node(OpKind::Add, vec![x1, x2], 2);
        let b = p.node(OpKind::Add, vec![a, g], 2);
        p.node(OpKind::Scatter, vec![b], 2);
        p.node(OpKind::Push, vec![b], 2);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("2 pull ops"), "{e}");
    }

    #[test]
    fn validate_rejects_missing_gather_slot() {
        // declares 2 children but only gathers slot 0
        let mut p = Program::new("bad", 2, 2);
        let x = p.node(OpKind::Pull, vec![], 2);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], 2);
        let a = p.node(OpKind::Add, vec![x, g], 2);
        p.node(OpKind::Scatter, vec![a], 2);
        p.node(OpKind::Push, vec![a], 2);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("slot 1 is never gathered"), "{e}");
    }

    #[test]
    fn validate_rejects_bad_param_shape() {
        let h = 4;
        let mut p = Program::new("bad", 1, h);
        let w = p.param("W", &[h, h + 1]); // wrong output width
        let x = p.node(OpKind::Pull, vec![], h);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let mm = p.node(OpKind::MatMul { param: w }, vec![x], h);
        let a = p.node(OpKind::Add, vec![mm, g], h);
        p.node(OpKind::Scatter, vec![a], h);
        p.node(OpKind::Push, vec![a], h);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("MatMul needs parameter"), "{e}");
    }

    #[test]
    fn validate_rejects_unread_push_source() {
        // push publishes a value that is not inside the scattered state
        let h = 4;
        let mut p = Program::new("bad", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let a = p.node(OpKind::Add, vec![x, g], h);
        let t = p.node(OpKind::Tanh, vec![a], h);
        p.node(OpKind::Scatter, vec![a], h);
        p.node(OpKind::Push, vec![t], h);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("not part of the scattered state"), "{e}");
    }

    #[test]
    fn validate_rejects_dead_nodes() {
        let h = 4;
        let mut p = Program::new("bad", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let a = p.node(OpKind::Add, vec![x, g], h);
        p.node(OpKind::Tanh, vec![a], h); // computed, never consumed
        p.node(OpKind::Scatter, vec![a], h);
        p.node(OpKind::Push, vec![a], h);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("never consumed"), "{e}");
    }

    #[test]
    fn validate_checks_softmax_and_broadcast_widths() {
        // SoftmaxCols keeps its input width; Broadcast requires a 1-col
        // input. Neither is elementwise (they are row-local, so they may
        // never join a fused group).
        assert!(!OpKind::SoftmaxCols.is_elementwise());
        assert!(!OpKind::Broadcast.is_elementwise());
        let h = 4;
        let mut p = Program::new("bad", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let a = p.node(OpKind::Add, vec![x, g], h);
        let s = p.node(OpKind::SoftmaxCols, vec![a], h - 1); // width mismatch
        p.node(OpKind::Scatter, vec![s], h);
        p.node(OpKind::Push, vec![s], h);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("SoftmaxCols"), "{e}");

        let mut p = Program::new("bad", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let a = p.node(OpKind::Add, vec![x, g], h);
        let b = p.node(OpKind::Broadcast, vec![a], h); // input is h cols, not 1
        p.node(OpKind::Scatter, vec![b], h);
        p.node(OpKind::Push, vec![b], h);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("Broadcast input must be 1 col"), "{e}");

        // the well-formed shape validates
        let mut p = Program::new("ok", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let g = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let a = p.node(OpKind::Add, vec![x, g], h);
        let sm = p.node(OpKind::SoftmaxCols, vec![a], h);
        let w1 = p.node(OpKind::SliceCols { start: 0, len: 1 }, vec![sm], 1);
        let bc = p.node(OpKind::Broadcast, vec![w1], h);
        let m = p.node(OpKind::Mul, vec![bc, a], h);
        p.node(OpKind::Scatter, vec![m], h);
        p.node(OpKind::Push, vec![m], h);
        p.validate().unwrap();
    }
}
