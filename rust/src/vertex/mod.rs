//! The vertex function `F` as a small static dataflow graph (paper §3.1,
//! Fig. 7), plus the §3.5 static analyses that the execution engine
//! consumes:
//!
//! * **fusion detection** — union-find over chains of element-wise
//!   operators; each fuse-able group can be replaced by one fused kernel
//!   (in this repo: the whole-cell fused Pallas artifact),
//! * **eager/lazy classification** (Proposition 2) — eager ops do not
//!   depend on `gather` (they can run before child results arrive, on a
//!   second stream); lazy ops do not feed `scatter` (their execution can
//!   be deferred past all batching tasks),
//! * structural **auto-differentiation** metadata (gather↔scatter,
//!   pull↔push duality, §3.4).
//!
//! The default engine executes F through the fused whole-cell artifact;
//! the `fusion=false` ablation interprets this op graph node-by-node, one
//! PJRT execution per operator (one "kernel launch" per op, like the
//! paper's unfused GPU baseline).

pub mod programs;

use std::collections::BTreeSet;

/// Op kinds. `param` indexes into the model's parameter list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpKind {
    /// gather(slot): child state -> dense task block
    Gather { slot: usize },
    /// pull(): external input (embedding row / upstream connector)
    Pull,
    /// scatter: publish this vertex's state for parents
    Scatter,
    /// push: publish to the external connector (heads read it)
    Push,
    /// x @ P (P is a model parameter)
    MatMul { param: usize },
    /// x + b (broadcast bias parameter)
    AddBias { param: usize },
    Add,
    Mul,
    Sigmoid,
    Tanh,
    /// take columns [start, start+len) of the input (host memcpy)
    SliceCols { start: usize, len: usize },
    /// concatenate inputs along columns (host memcpy)
    ConcatCols,
}

impl OpKind {
    /// Element-wise ops are the fusion candidates (§3.5: "+, -, ×, ÷,
    /// tanh, sigmoid").
    pub fn is_elementwise(&self) -> bool {
        matches!(self, OpKind::Add | OpKind::Mul | OpKind::Sigmoid | OpKind::Tanh)
    }

    /// The §3.4 adjoint duality for the four message-passing primitives.
    pub fn adjoint_primitive(&self) -> Option<OpKind> {
        match self {
            OpKind::Gather { .. } => Some(OpKind::Scatter),
            OpKind::Scatter => Some(OpKind::Gather { slot: 0 }),
            OpKind::Pull => Some(OpKind::Push),
            OpKind::Push => Some(OpKind::Pull),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct OpNode {
    pub kind: OpKind,
    /// input node ids
    pub ins: Vec<usize>,
    /// output width (columns per vertex)
    pub cols: usize,
}

/// The vertex function as a DAG of ops. Node ids are topological by
/// construction (builders append in dependency order).
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub nodes: Vec<OpNode>,
    /// number of child slots (1 chain, 2 binary tree)
    pub n_children: usize,
    /// columns of the scattered state
    pub state_cols: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Analysis {
    /// fuse-able groups (node ids), each of size >= 2
    pub fusion_groups: Vec<Vec<usize>>,
    /// eager nodes: gather is NOT an ancestor (can run on stream 2)
    pub eager: BTreeSet<usize>,
    /// lazy nodes: scatter is NOT a descendant (deferrable)
    pub lazy: BTreeSet<usize>,
}

impl Program {
    pub fn node(&mut self, kind: OpKind, ins: Vec<usize>, cols: usize) -> usize {
        for &i in &ins {
            assert!(i < self.nodes.len(), "forward reference in program");
        }
        self.nodes.push(OpNode { kind, ins, cols });
        self.nodes.len() - 1
    }

    fn reachable_from(&self, sources: &[usize]) -> Vec<bool> {
        // nodes are topologically ordered, one forward sweep suffices
        let mut reach = vec![false; self.nodes.len()];
        for &s in sources {
            reach[s] = true;
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if !reach[i] && n.ins.iter().any(|&j| reach[j]) {
                reach[i] = true;
            }
        }
        reach
    }

    fn reaches(&self, targets: &[usize]) -> Vec<bool> {
        // reverse reachability: does node i reach any target?
        let mut reach = vec![false; self.nodes.len()];
        for &t in targets {
            reach[t] = true;
        }
        for i in (0..self.nodes.len()).rev() {
            if reach[i] {
                for &j in &self.nodes[i].ins {
                    reach[j] = true;
                }
            }
        }
        reach
    }

    fn ids_of(&self, pred: impl Fn(&OpKind) -> bool) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| pred(&n.kind))
            .map(|(i, _)| i)
            .collect()
    }

    /// Run the §3.5 static analyses.
    pub fn analyze(&self) -> Analysis {
        let gathers = self.ids_of(|k| matches!(k, OpKind::Gather { .. }));
        let scatters = self.ids_of(|k| matches!(k, OpKind::Scatter));

        // ---- Proposition 2 ----
        let below_gather = self.reachable_from(&gathers);
        let feeds_scatter = self.reaches(&scatters);
        let mut eager = BTreeSet::new();
        let mut lazy = BTreeSet::new();
        for i in 0..self.nodes.len() {
            let is_gather = gathers.contains(&i);
            let is_scatter = scatters.contains(&i);
            if !below_gather[i] && !is_gather {
                eager.insert(i);
            }
            if !feeds_scatter[i] && !is_scatter {
                lazy.insert(i);
            }
        }

        // ---- fusion: union-find over element-wise adjacency ----
        let mut parent: Vec<usize> = (0..self.nodes.len()).collect();
        fn find(p: &mut Vec<usize>, x: usize) -> usize {
            if p[x] != x {
                let r = find(p, p[x]);
                p[x] = r;
            }
            p[x]
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.kind.is_elementwise() {
                continue;
            }
            for &j in &n.ins {
                if self.nodes[j].kind.is_elementwise() {
                    let (a, b) = (find(&mut parent, i), find(&mut parent, j));
                    if a != b {
                        parent[a] = b;
                    }
                }
            }
        }
        let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
            Default::default();
        for i in 0..self.nodes.len() {
            if self.nodes[i].kind.is_elementwise() {
                groups.entry(find(&mut parent, i)).or_default().push(i);
            }
        }
        let fusion_groups: Vec<Vec<usize>> =
            groups.into_values().filter(|g| g.len() >= 2).collect();

        Analysis { fusion_groups, eager, lazy }
    }

    /// Number of PJRT executions ("kernel launches") the unfused
    /// interpretation needs per task: every non-memory op.
    pub fn launches_unfused(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| {
                matches!(
                    n.kind,
                    OpKind::MatMul { .. }
                        | OpKind::AddBias { .. }
                        | OpKind::Add
                        | OpKind::Mul
                        | OpKind::Sigmoid
                        | OpKind::Tanh
                )
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::programs::*;
    use super::*;

    #[test]
    fn lstm_program_analysis_matches_fig7() {
        let p = lstm_program(8);
        let a = p.analyze();
        // pull and the x-side matmul are eager (don't depend on gather)
        let pulls = p.ids_of(|k| matches!(k, OpKind::Pull));
        assert!(pulls.iter().all(|i| a.eager.contains(i)));
        let xmms: Vec<usize> = p
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                matches!(n.kind, OpKind::MatMul { .. })
                    && n.ins.iter().any(|&j| pulls.contains(&j))
            })
            .map(|(i, _)| i)
            .collect();
        assert!(!xmms.is_empty());
        assert!(xmms.iter().all(|i| a.eager.contains(i)));
        // push is lazy
        let pushes = p.ids_of(|k| matches!(k, OpKind::Push));
        assert!(pushes.iter().all(|i| a.lazy.contains(i)));
        // the h-side matmul is NOT eager (consumes gathered state)
        let gathers = p.ids_of(|k| matches!(k, OpKind::Gather { .. }));
        assert!(!gathers.is_empty());
        // there is at least one sizeable fuse-able element-wise group
        // (the gate nonlinearity + cell-update chain of Fig. 7)
        assert!(!a.fusion_groups.is_empty());
        assert!(a.fusion_groups.iter().any(|g| g.len() >= 4));
    }

    #[test]
    fn scatter_never_lazy_gather_never_eager() {
        for p in [lstm_program(4), treelstm_program(4), treefc_program(4)] {
            let a = p.analyze();
            for (i, n) in p.nodes.iter().enumerate() {
                if matches!(n.kind, OpKind::Scatter) {
                    assert!(!a.lazy.contains(&i));
                }
                if matches!(n.kind, OpKind::Gather { .. }) {
                    assert!(!a.eager.contains(&i));
                }
            }
        }
    }

    #[test]
    fn adjoint_duality() {
        assert_eq!(
            OpKind::Gather { slot: 1 }.adjoint_primitive(),
            Some(OpKind::Scatter)
        );
        assert_eq!(OpKind::Pull.adjoint_primitive(), Some(OpKind::Push));
        assert_eq!(OpKind::Push.adjoint_primitive(), Some(OpKind::Pull));
        assert_eq!(OpKind::Add.adjoint_primitive(), None);
    }

    #[test]
    fn fusion_groups_are_elementwise_only() {
        for p in [lstm_program(8), treelstm_program(8)] {
            let a = p.analyze();
            for g in &a.fusion_groups {
                for &i in g {
                    assert!(p.nodes[i].kind.is_elementwise());
                }
            }
        }
    }

    #[test]
    fn launch_counts() {
        // fused cell = 1 launch; unfused LSTM needs ~a dozen
        assert!(lstm_program(8).launches_unfused() >= 10);
        assert!(treelstm_program(8).launches_unfused() >= 15);
        assert!(treefc_program(8).launches_unfused() >= 5);
    }
}
