//! The `F` compiler: static optimization of a validated [`Program`]
//! (paper §3.5 — "allow for the use of static graph optimization
//! techniques on pre-defined operations in F").
//!
//! [`Program::optimize`] runs a fixed pass pipeline and lowers the op DAG
//! into an [`OptProgram`] — a preplanned execution *schedule* the host
//! interpreter executes per frontier level instead of op-by-op:
//!
//! 1. **CSE** — ops with identical kind and (canonicalized) inputs merge
//!    into one node; consumers are rewired to the canonical node.
//! 2. **DCE** — nodes no longer reachable from `scatter`/`push` (only
//!    possible after CSE rewiring; `validate()` rejects dead nodes in
//!    source programs) are removed and ids compacted.
//! 3. **Gate-matmul concatenation** — `MatMul` nodes sharing the same
//!    input (e.g. the LSTM/GRU gate projections of `x`, or Tree-LSTM's
//!    `Wiou`/`Wf` projections) merge into one wide GEMM over the
//!    column-concatenated parameter matrices ([`WideGemm`]); the merged
//!    outputs are laid out adjacently so downstream ops read slices of
//!    the wide result in place.
//! 4. **View folding** — every `SliceCols` becomes a zero-copy *view*
//!    (an offset into its input's storage), and a `ConcatCols` feeding
//!    only `scatter`/`push` has its inputs allocated directly inside its
//!    region, eliminating the per-row memcpys entirely.
//! 5. **Elementwise fusion** — maximal runs of same-width
//!    `Add`/`Mul`/`Sigmoid`/`Tanh`/`OneMinus`/`AddBias` ops collapse into
//!    one [`FusedGroup`] executed as a single sweep per row.
//!
//! ## The bitwise contract
//!
//! Every pass preserves the exact f32 arithmetic of the unoptimized
//! interpreter **per output element**: wide GEMMs keep each output
//! column's k-reduction order (concatenation is along columns, reduction
//! is along rows), views read the very bytes the eliminated copy would
//! have produced, and fused sweeps perform the same scalar ops in the
//! same order per lane. The structural backward executes the *original*
//! per-node VJPs in the original reverse order over the optimized value
//! layout — adjoint slots are never aliased — so forward **and** backward
//! results are bitwise identical to [`super::interp::ProgramCell`]'s
//! reference path at every thread count (property-tested for all
//! registered cells). The exception is CSE on programs that actually
//! contain duplicate subexpressions (none of the shipped cells do):
//! merging duplicates preserves bitwise forward values but can reassociate
//! adjoint accumulation; such programs are gradcheck-verified instead.
//!
//! The plan is bound to parameter tensors by
//! [`ProgramCell`](super::interp::ProgramCell) (which concatenates the
//! merged weight matrices once at bind time) and executed per frontier
//! level by the `LevelCell` hooks in `exec::parallel`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::{OpKind, OpNode, ParamSpec, Program, ProgramMeta};

/// What the pass pipeline did — surfaced by `cavs cells` and the opt
/// unit tests.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OptStats {
    /// real (non-scatter/push) ops in the source program
    pub ops_before: usize,
    /// scheduled steps after optimization (a fused group counts as one)
    pub ops_after: usize,
    /// duplicate ops rewired by CSE
    pub cse_merged: usize,
    /// nodes removed by DCE (includes the CSE duplicates)
    pub dce_removed: usize,
    /// matmuls folded into a wider GEMM (segments beyond each first)
    pub gemms_merged: usize,
    /// fused elementwise groups of size >= 2
    pub fused_groups: usize,
    /// elementwise ops living inside those groups
    pub fused_ops: usize,
    /// slice/concat per-row copies eliminated by view folding
    pub folded_copies: usize,
}

/// One segment of a wide GEMM: the original `MatMul` node it came from.
#[derive(Debug, Clone)]
pub struct GemmSeg {
    pub node: usize,
    pub param: usize,
    pub cols: usize,
}

/// A (possibly single-segment) GEMM over the column-concatenated
/// parameters of all `MatMul`s sharing `input`. Segment outputs are laid
/// out adjacently starting at the first segment's storage.
#[derive(Debug, Clone)]
pub struct WideGemm {
    /// node id of the shared input
    pub input: usize,
    /// input columns (the reduction dimension)
    pub k: usize,
    /// total output columns (sum of segment widths)
    pub n: usize,
    pub segs: Vec<GemmSeg>,
}

/// A maximal run of same-width elementwise ops executed as one sweep.
#[derive(Debug, Clone)]
pub struct FusedGroup {
    pub width: usize,
    /// member node ids in topological order
    pub nodes: Vec<usize>,
}

/// How a node's storage was assigned by view folding — retained on the
/// [`OptProgram`] so [`OptProgram::verify`] can re-walk the alias chains
/// instead of trusting the resolved addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Alloc {
    /// owns a fresh region of the forward tape
    Fresh,
    /// view into another node's storage at a column offset — a folded
    /// slice, aliased concat input, or non-leading wide-GEMM segment
    /// (`At(parent, off)`)
    At(usize, usize),
    /// no storage (scatter/push)
    None,
}

/// One step of the optimized forward schedule. Steps execute in order;
/// view nodes (folded slices, aliased concat inputs, non-leading GEMM
/// segments) emit no step at all.
#[derive(Debug, Clone)]
pub enum Step {
    /// copy the pull input `x` into the node's storage
    Pull { node: usize },
    /// copy child slot state into the node's storage
    Gather { node: usize, slot: usize },
    /// materialize a concat (copies only the inputs that could not be
    /// aliased into the concat's region)
    Concat { node: usize },
    /// run wide GEMM `wide` (writes all its segments at once)
    Gemm { wide: usize },
    /// run fused elementwise group `group`
    Fused { group: usize },
    /// run a row-local but non-elementwise op (`SoftmaxCols`/`Broadcast`):
    /// each output column may read every input column, so it can never
    /// join a fused group or be folded into a view of its input
    RowOp { node: usize },
}

/// The compiled form of a vertex function: the post-CSE/DCE op graph plus
/// a value layout (with aliasing views), a forward schedule, and the
/// merged-GEMM / fused-group descriptors. Adjoint slots are laid out
/// separately and never aliased — the backward sweep is the original
/// per-node VJP chain over this layout.
#[derive(Debug, Clone)]
pub struct OptProgram {
    pub name: String,
    pub meta: ProgramMeta,
    /// compacted node list (ids differ from the source program after DCE)
    pub nodes: Vec<OpNode>,
    /// parameter declarations (identical to the source program's)
    pub params: Vec<ParamSpec>,
    /// per-node value offset into the forward tape (`usize::MAX` for
    /// scatter/push, which have no storage)
    pub addr: Vec<usize>,
    /// per-node adjoint offset (`usize::MAX` for scatter/push); never
    /// aliased, one slot per node
    pub aoff: Vec<usize>,
    /// forward tape floats per row
    pub tape_cols: usize,
    /// adjoint tape floats per row
    pub adj_cols: usize,
    /// per-node storage assignment (the alias-chain record behind
    /// `addr`; [`Self::verify`] re-resolves it)
    pub alloc: Vec<Alloc>,
    /// forward tape row pitch for *level* (multi-row) execution:
    /// `tape_cols` rounded up to 16 floats (one 64-byte cache line) so a
    /// worker shard's sub-block never shares a line with its neighbour's
    /// and SIMD row bases stay line-aligned relative to each other. The
    /// per-row [`HostCell`](crate::exec::parallel::HostCell) path keeps
    /// the dense `tape_cols` pitch; the padding is never read
    pub tape_stride: usize,
    /// adjoint row pitch for level execution (see [`Self::tape_stride`])
    pub adj_stride: usize,
    /// node whose value the scatter publishes
    pub scatter_src: usize,
    pub steps: Vec<Step>,
    pub wide: Vec<WideGemm>,
    pub fused: Vec<FusedGroup>,
    pub stats: OptStats,
}

impl Program {
    /// Compile this (validated) program: run the pass pipeline, lower to
    /// an [`OptProgram`], and prove the resulting layout sound. Errors if
    /// the program fails validation or the layout fails verification.
    pub fn optimize(&self) -> Result<OptProgram> {
        let meta = self.validate()?;
        let opt = build(self, meta)?;
        opt.verify().with_context(|| {
            format!("program '{}': compiled layout failed verification", self.name)
        })?;
        Ok(opt)
    }
}

fn is_real(kind: &OpKind) -> bool {
    !matches!(kind, OpKind::Scatter | OpKind::Push)
}

/// Key for structural equality of ops (CSE). `OpKind` carries its
/// immediate fields (slot/param/start/len), so two ops are equal iff they
/// compute the same function of the same inputs.
type CseKey = (OpKind, Vec<usize>);

fn build(p: &Program, meta: ProgramMeta) -> Result<OptProgram> {
    let n = p.nodes.len();
    let mut stats = OptStats {
        ops_before: p.nodes.iter().filter(|x| is_real(&x.kind)).count(),
        ..OptStats::default()
    };

    // reject programs that consume a scatter/push value: those nodes have
    // no storage (the reference interpreter leaves their tape slot
    // unwritten too — such programs are ill-formed for execution)
    for (i, node) in p.nodes.iter().enumerate() {
        for &j in &node.ins {
            if !is_real(&p.nodes[j].kind) {
                bail!(
                    "program '{}': node {i} consumes the value of node {j} \
                     ({:?}), which produces none",
                    p.name,
                    p.nodes[j].kind
                );
            }
        }
    }

    // ---- pass 1: common-subexpression elimination --------------------
    // rep[i] = canonical node for i (identity for non-duplicates). The
    // message-passing skeleton (pull/gather/scatter/push) is never
    // merged: validate() already guarantees it has no duplicates.
    let mut rep: Vec<usize> = (0..n).collect();
    {
        let mut seen: BTreeMap<CseKey, usize> = BTreeMap::new();
        for (i, node) in p.nodes.iter().enumerate() {
            if matches!(
                node.kind,
                OpKind::Pull | OpKind::Gather { .. } | OpKind::Scatter | OpKind::Push
            ) {
                continue;
            }
            let key: CseKey = (
                node.kind.clone(),
                node.ins.iter().map(|&j| rep[j]).collect(),
            );
            match seen.get(&key) {
                Some(&c) => {
                    rep[i] = c;
                    stats.cse_merged += 1;
                }
                None => {
                    seen.insert(key, i);
                }
            }
        }
    }

    // ---- pass 2: dead-code elimination + compaction ------------------
    // Liveness flows backward from scatter and push through rep-resolved
    // edges; CSE duplicates (rep[i] != i) are dead by construction.
    let mut live = vec![false; n];
    for (i, node) in p.nodes.iter().enumerate() {
        if matches!(node.kind, OpKind::Scatter | OpKind::Push) {
            live[i] = true;
        }
    }
    for i in (0..n).rev() {
        if live[i] && rep[i] == i {
            for &j in &p.nodes[i].ins {
                live[rep[j]] = true;
            }
        }
    }
    stats.dce_removed = (0..n)
        .filter(|&i| !(live[i] && rep[i] == i) && is_real(&p.nodes[i].kind))
        .count();

    let mut new_id = vec![usize::MAX; n];
    let mut nodes: Vec<OpNode> = Vec::new();
    for i in 0..n {
        if live[i] && rep[i] == i {
            new_id[i] = nodes.len();
            nodes.push(OpNode {
                kind: p.nodes[i].kind.clone(),
                ins: p.nodes[i].ins.iter().map(|&j| new_id[rep[j]]).collect(),
                cols: p.nodes[i].cols,
            });
        }
    }
    let n2 = nodes.len();
    debug_assert!(nodes
        .iter()
        .all(|node| node.ins.iter().all(|&j| j < usize::MAX)));

    let scatter_node = nodes
        .iter()
        .position(|x| matches!(x.kind, OpKind::Scatter))
        .expect("validated program has a scatter");
    let scatter_src = nodes[scatter_node].ins[0];

    // ---- pass 3: gate-matmul concatenation ---------------------------
    // Group matmuls by shared input; every matmul belongs to exactly one
    // WideGemm (singletons included — uniform execution). Within a group,
    // segments keep node order and their outputs are laid out adjacently.
    let mut by_input: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, node) in nodes.iter().enumerate() {
        if matches!(node.kind, OpKind::MatMul { .. }) {
            by_input.entry(node.ins[0]).or_default().push(i);
        }
    }
    let mut wide: Vec<WideGemm> = Vec::new();
    // wide_of[node] = (wide index, segment index, column offset)
    let mut wide_of: Vec<Option<(usize, usize, usize)>> = vec![None; n2];
    for (&input, mms) in &by_input {
        let k = nodes[input].cols;
        let mut segs = Vec::with_capacity(mms.len());
        let mut off = 0usize;
        for &m in mms {
            let param = match nodes[m].kind {
                OpKind::MatMul { param } => param,
                _ => unreachable!(),
            };
            wide_of[m] = Some((wide.len(), segs.len(), off));
            segs.push(GemmSeg { node: m, param, cols: nodes[m].cols });
            off += nodes[m].cols;
        }
        if mms.len() > 1 {
            stats.gemms_merged += mms.len() - 1;
        }
        wide.push(WideGemm { input, k, n: off, segs });
    }

    // ---- pass 4: value layout with view folding ----------------------
    // Alloc::At(parent, off) chains resolve to a fresh region; chains can
    // point forward (concat aliasing) but never cycle: a node only
    // aliases into the region of a concat it feeds (higher id) or of an
    // earlier GEMM segment, and a concat's own region is fresh or again
    // aliased into a strictly later concat.
    let mut alloc = vec![Alloc::Fresh; n2];
    for (i, node) in nodes.iter().enumerate() {
        match node.kind {
            OpKind::Scatter | OpKind::Push => alloc[i] = Alloc::None,
            OpKind::SliceCols { start, .. } => {
                alloc[i] = Alloc::At(node.ins[0], start);
                stats.folded_copies += 1;
            }
            OpKind::MatMul { .. } => {
                if let Some((w, seg, off)) = wide_of[i] {
                    if seg > 0 {
                        alloc[i] = Alloc::At(wide[w].segs[0].node, off);
                    }
                }
            }
            _ => {}
        }
    }
    // concat aliasing: only when the concat's sole consumers are
    // scatter/push (its region then receives the backward seed before any
    // other adjoint contribution, keeping the VJP order identical to the
    // reference — see the module docs), and only for inputs that are
    // plain fresh nodes used exactly once in the input list.
    for (i, node) in nodes.iter().enumerate() {
        if !matches!(node.kind, OpKind::ConcatCols) {
            continue;
        }
        let only_sinks = nodes.iter().all(|q| {
            !q.ins.contains(&i) || matches!(q.kind, OpKind::Scatter | OpKind::Push)
        });
        if !only_sinks {
            continue;
        }
        let mut off = 0usize;
        for &src in &node.ins {
            let w = nodes[src].cols;
            let once = node.ins.iter().filter(|&&s| s == src).count() == 1;
            // a leading segment of a multi-segment GEMM keeps its fresh
            // region: the wide GEMM writes *all* segments at its address,
            // which must not land inside a concat region
            let narrow = wide_of[src]
                .map_or(true, |(w_idx, _, _)| wide[w_idx].segs.len() == 1);
            if once && narrow && matches!(alloc[src], Alloc::Fresh) {
                alloc[src] = Alloc::At(i, off);
                stats.folded_copies += 1;
            }
            off += w;
        }
    }

    // fresh allocations in id order, then resolve the alias chains. A
    // multi-segment GEMM leader's fresh region must hold the *whole* wide
    // output (its non-leading segments alias `At(leader, off)` beyond the
    // leader's own cols; the leader is always Fresh — the concat pass
    // skips multi-segment GEMM nodes).
    let mut addr = vec![usize::MAX; n2];
    let mut tape_cols = 0usize;
    for i in 0..n2 {
        if matches!(alloc[i], Alloc::Fresh) {
            addr[i] = tape_cols;
            let width = match wide_of[i] {
                Some((w, 0, _)) if wide[w].segs.len() > 1 => wide[w].n,
                _ => nodes[i].cols,
            };
            tape_cols += width;
        }
    }
    fn resolve(i: usize, alloc: &[Alloc], addr: &mut [usize]) -> usize {
        if addr[i] != usize::MAX {
            return addr[i];
        }
        let a = match alloc[i] {
            Alloc::At(parent, off) => resolve(parent, alloc, addr) + off,
            Alloc::Fresh | Alloc::None => unreachable!("unresolved fresh/none"),
        };
        addr[i] = a;
        a
    }
    for i in 0..n2 {
        if matches!(alloc[i], Alloc::At(..)) {
            resolve(i, &alloc, &mut addr);
        }
    }

    // adjoint layout: one private slot per value-producing node
    let mut aoff = vec![usize::MAX; n2];
    let mut adj_cols = 0usize;
    for (i, node) in nodes.iter().enumerate() {
        if is_real(&node.kind) {
            aoff[i] = adj_cols;
            adj_cols += node.cols;
        }
    }

    // ---- pass 5: schedule + elementwise fusion -----------------------
    // Steps are emitted in node order; every emitted step closes the open
    // fused group, so any value a group member reads was produced either
    // by an earlier member or by a step emitted before the group's own
    // position (view chains always resolve to producers at or before
    // their own id).
    let mut steps: Vec<Step> = Vec::new();
    let mut fused: Vec<FusedGroup> = Vec::new();
    let mut open: Option<usize> = None;
    for (i, node) in nodes.iter().enumerate() {
        match &node.kind {
            OpKind::Pull => {
                steps.push(Step::Pull { node: i });
                open = None;
            }
            OpKind::Gather { slot } => {
                steps.push(Step::Gather { node: i, slot: *slot });
                open = None;
            }
            OpKind::SliceCols { .. } => {} // pure view
            OpKind::ConcatCols => {
                // a copy step only for inputs that could not be aliased
                let mut off = 0usize;
                let mut needs_copy = false;
                for &src in &node.ins {
                    if addr[src] != addr[i] + off {
                        needs_copy = true;
                    }
                    off += nodes[src].cols;
                }
                if needs_copy {
                    steps.push(Step::Concat { node: i });
                    open = None;
                }
            }
            OpKind::MatMul { .. } => {
                if let Some((w, 0, _)) = wide_of[i] {
                    steps.push(Step::Gemm { wide: w });
                    open = None;
                }
                // non-leading segments execute with their leader
            }
            OpKind::AddBias { .. }
            | OpKind::Add
            | OpKind::Mul
            | OpKind::Sigmoid
            | OpKind::Tanh
            | OpKind::OneMinus => {
                match open {
                    Some(g) if fused[g].width == node.cols => {
                        fused[g].nodes.push(i);
                    }
                    _ => {
                        fused.push(FusedGroup { width: node.cols, nodes: vec![i] });
                        steps.push(Step::Fused { group: fused.len() - 1 });
                        open = Some(fused.len() - 1);
                    }
                }
            }
            OpKind::SoftmaxCols | OpKind::Broadcast => {
                steps.push(Step::RowOp { node: i });
                open = None;
            }
            OpKind::Scatter | OpKind::Push => {}
        }
    }
    stats.fused_groups = fused.iter().filter(|g| g.nodes.len() >= 2).count();
    stats.fused_ops = fused
        .iter()
        .filter(|g| g.nodes.len() >= 2)
        .map(|g| g.nodes.len())
        .sum();
    stats.ops_after = steps.len();

    Ok(OptProgram {
        name: p.name.clone(),
        meta,
        nodes,
        params: p.params.clone(),
        addr,
        aoff,
        alloc,
        tape_cols,
        adj_cols,
        tape_stride: tape_cols.next_multiple_of(16),
        adj_stride: adj_cols.next_multiple_of(16),
        scatter_src,
        steps,
        wide,
        fused,
        stats,
    })
}

impl OptProgram {
    /// Columns of the pull input (convenience mirror of `meta.x_cols`).
    pub fn x_cols(&self) -> usize {
        self.meta.x_cols
    }

    /// The layout soundness pass (DESIGN.md §13): alias chains acyclic
    /// and in-bounds, view segments within their backing values, step
    /// outputs disjoint from their input views, adjoint slots never
    /// aliased, 16-float stride padding respected. Runs at every
    /// [`Program::optimize`] (hence cell registration) and again at cell
    /// bind — never in the per-step hot path.
    pub fn verify(
        &self,
    ) -> std::result::Result<
        crate::analysis::layout::LayoutReport,
        crate::analysis::SoundnessError,
    > {
        crate::analysis::layout::verify(self)
    }

    /// Human-readable `before→after` op-count summary for `cavs cells`.
    pub fn summary(&self) -> String {
        format!("{}→{}", self.stats.ops_before, self.stats.ops_after)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{interp::ProgramCell, programs};
    use super::*;
    use crate::exec::parallel::HostCell;
    use crate::util::rng::Rng;

    fn shipped() -> Vec<Program> {
        vec![
            programs::lstm_program(6),
            programs::treelstm_program(6),
            programs::treefc_program(6),
            programs::gru_program(6),
            programs::cstreelstm_program(6),
        ]
    }

    /// Forward + backward + param grads of the optimized cell are bitwise
    /// identical to the reference interpreter on one random row.
    fn assert_row_equivalence(p: Program, seed: u64) {
        let name = p.name.clone();
        let mut rng = Rng::new(seed);
        let reference = ProgramCell::random(p.clone(), &mut rng, 0.2).unwrap();
        let optimized =
            ProgramCell::optimized(p, reference.params().to_vec()).unwrap();
        let mut rng = Rng::new(seed ^ 0x5eed);
        let xc = reference.x_cols();
        let asc = reference.arity() * reference.state_cols();
        let sc = reference.state_cols();
        let x: Vec<f32> = (0..xc).map(|_| rng.normal_f32(0.5)).collect();
        let s: Vec<f32> = (0..asc).map(|_| rng.normal_f32(0.5)).collect();
        let g: Vec<f32> = (0..sc).map(|_| rng.normal_f32(1.0)).collect();

        let mut out_a = vec![0.0f32; sc];
        let mut out_b = vec![0.0f32; sc];
        let mut tmp_a = vec![0.0f32; reference.fwd_scratch_cols().max(1)];
        let mut tmp_b = vec![0.0f32; optimized.fwd_scratch_cols().max(1)];
        reference.forward(&x, &s, &mut out_a, &mut tmp_a);
        optimized.forward(&x, &s, &mut out_b, &mut tmp_b);
        assert_eq!(out_a, out_b, "{name}: forward diverges");

        let mut gx_a = vec![0.0f32; xc];
        let mut gx_b = vec![0.0f32; xc];
        let mut gs_a = vec![0.0f32; asc];
        let mut gs_b = vec![0.0f32; asc];
        let mut btmp_a = vec![0.0f32; reference.bwd_scratch_cols().max(1)];
        let mut btmp_b = vec![0.0f32; optimized.bwd_scratch_cols().max(1)];
        reference.backward(&x, &s, &g, &mut gx_a, &mut gs_a, &mut btmp_a);
        optimized.backward(&x, &s, &g, &mut gx_b, &mut gs_b, &mut btmp_b);
        assert_eq!(gx_a, gx_b, "{name}: gx diverges");
        assert_eq!(gs_a, gs_b, "{name}: gs diverges");

        let mut pg_a: Vec<Vec<f32>> =
            reference.params().iter().map(|q| vec![0.0; q.len()]).collect();
        let mut pg_b = pg_a.clone();
        let mut ptmp_a = vec![0.0f32; reference.pg_scratch_cols().max(1)];
        let mut ptmp_b = vec![0.0f32; optimized.pg_scratch_cols().max(1)];
        reference.acc_param_grads(&x, &s, &g, &mut pg_a, &mut ptmp_a);
        optimized.acc_param_grads(&x, &s, &g, &mut pg_b, &mut ptmp_b);
        assert_eq!(pg_a, pg_b, "{name}: param grads diverge");
    }

    #[test]
    fn optimized_row_bitwise_matches_reference_for_all_cells() {
        for (i, p) in shipped().into_iter().enumerate() {
            assert_row_equivalence(p, 100 + i as u64);
        }
    }

    #[test]
    fn shipped_cells_optimize_without_dce_or_cse() {
        // the hand-written builders are already minimal: the cleanup
        // passes must be no-ops, and the win comes from merging/fusion
        for p in shipped() {
            let o = p.optimize().unwrap();
            assert_eq!(o.stats.cse_merged, 0, "{}", p.name);
            assert_eq!(o.stats.dce_removed, 0, "{}", p.name);
            assert!(
                o.stats.ops_after < o.stats.ops_before,
                "{}: schedule did not shrink ({} -> {})",
                p.name,
                o.stats.ops_before,
                o.stats.ops_after
            );
        }
    }

    #[test]
    fn lstm_views_and_fusion() {
        let p = programs::lstm_program(8);
        let o = p.optimize().unwrap();
        // 6 SliceCols + the scatter ConcatCols (2 inputs) fold away
        assert!(o.stats.folded_copies >= 8, "{:?}", o.stats);
        // the gate nonlinearity + cell-update chain is one fused sweep
        assert!(
            o.fused.iter().any(|g| g.nodes.len() >= 8),
            "groups: {:?}",
            o.fused
        );
        // gates are already packed: nothing to merge
        assert_eq!(o.stats.gemms_merged, 0);
        // optimized tape drops the view slots
        let reference = ProgramCell::new(p, dummy_params(&o.params)).unwrap();
        assert!(o.tape_cols < reference.fwd_scratch_cols());
    }

    fn dummy_params(specs: &[ParamSpec]) -> Vec<Vec<f32>> {
        specs.iter().map(|s| vec![0.1; s.elements()]).collect()
    }

    #[test]
    fn treelstm_gate_matmuls_concatenate() {
        let o = programs::treelstm_program(8).optimize().unwrap();
        // x @ Wiou and x @ Wf share the input x and merge into one wide
        // GEMM (the h-side projections keep distinct inputs)
        assert_eq!(o.stats.gemms_merged, 1, "{:?}", o.stats);
        let merged = o.wide.iter().find(|w| w.segs.len() == 2).unwrap();
        assert_eq!(merged.n, merged.segs[0].cols + merged.segs[1].cols);
        // the second segment's storage is adjacent to the first's
        let a = o.addr[merged.segs[0].node];
        let b = o.addr[merged.segs[1].node];
        assert_eq!(b, a + merged.segs[0].cols);
        // the leader's fresh region reserves the WHOLE wide output: no
        // other node's storage may intersect [a, a + n)
        let wide_end = a + merged.n;
        assert!(wide_end <= o.tape_cols);
        let seg_nodes: Vec<usize> = merged.segs.iter().map(|s| s.node).collect();
        for (i, node) in o.nodes.iter().enumerate() {
            if o.addr[i] == usize::MAX || seg_nodes.contains(&i) {
                continue;
            }
            // skip views *into* the wide region (slices of the segments)
            let is_view_of_seg = matches!(node.kind, OpKind::SliceCols { .. })
                && seg_nodes.contains(&node.ins[0]);
            if is_view_of_seg {
                continue;
            }
            let (lo, hi) = (o.addr[i], o.addr[i] + node.cols);
            assert!(
                hi <= a || lo >= wide_end,
                "node {i} ({:?}) storage [{lo},{hi}) collides with the wide \
                 GEMM region [{a},{wide_end})",
                node.kind
            );
        }
    }

    #[test]
    fn scatter_concat_inputs_alias_into_state_region() {
        let o = programs::lstm_program(4).optimize().unwrap();
        // sout = Concat(c2, h2) feeds only scatter/push: no Concat step
        assert!(
            !o.steps.iter().any(|s| matches!(s, Step::Concat { .. })),
            "steps: {:?}",
            o.steps
        );
        let concat = o
            .nodes
            .iter()
            .position(|n| matches!(n.kind, OpKind::ConcatCols))
            .unwrap();
        let c2 = o.nodes[concat].ins[0];
        let h2 = o.nodes[concat].ins[1];
        assert_eq!(o.addr[c2], o.addr[concat]);
        assert_eq!(o.addr[h2], o.addr[concat] + o.nodes[c2].cols);
    }

    /// A program with genuine duplicate subexpressions: CSE merges them,
    /// DCE removes the dup, and the forward stays bitwise identical.
    #[test]
    fn cse_merges_duplicates_and_dce_removes_them() {
        let h = 4;
        let mut p = Program::new("dup", 1, h);
        let w = p.param("W", &[h, h]);
        let x = p.node(OpKind::Pull, vec![], h);
        let s = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let m1 = p.node(OpKind::MatMul { param: w }, vec![x], h);
        let m2 = p.node(OpKind::MatMul { param: w }, vec![x], h); // dup of m1
        let t1 = p.node(OpKind::Tanh, vec![m1], h);
        let t2 = p.node(OpKind::Tanh, vec![m2], h); // dup after rewiring
        let a = p.node(OpKind::Add, vec![t1, t2], h);
        let b = p.node(OpKind::Add, vec![a, s], h);
        p.node(OpKind::Scatter, vec![b], h);
        p.node(OpKind::Push, vec![b], h);
        let o = p.optimize().unwrap();
        assert_eq!(o.stats.cse_merged, 2, "{:?}", o.stats);
        assert_eq!(o.stats.dce_removed, 2, "{:?}", o.stats);
        assert_eq!(o.nodes.len(), p.nodes.len() - 2);

        // forward bitwise equivalence (the Add reads the canonical node
        // twice — same value bits as adding two separately-computed dups)
        let params = vec![vec![0.3f32; h * h]];
        let reference = ProgramCell::new(p.clone(), params.clone()).unwrap();
        let optimized = ProgramCell::optimized(p, params).unwrap();
        let x = [0.7f32, -0.2, 0.4, 1.1];
        let s = [0.1f32, 0.2, -0.3, 0.0];
        let mut oa = [0.0f32; 4];
        let mut ob = [0.0f32; 4];
        let mut ta = vec![0.0f32; reference.fwd_scratch_cols()];
        let mut tb = vec![0.0f32; optimized.fwd_scratch_cols()];
        reference.forward(&x, &s, &mut oa, &mut ta);
        optimized.forward(&x, &s, &mut ob, &mut tb);
        assert_eq!(oa, ob);
    }

    #[test]
    fn fused_groups_split_on_width_changes() {
        let o = programs::lstm_program(8).optimize().unwrap();
        // {gsum, pre} at 4h and the h-wide gate chain are separate groups
        let widths: Vec<(usize, usize)> =
            o.fused.iter().map(|g| (g.width, g.nodes.len())).collect();
        assert!(
            widths.contains(&(32, 2)),
            "expected a 4h-wide 2-op group, got {widths:?}"
        );
        assert!(widths.iter().any(|&(w, len)| w == 8 && len >= 8), "{widths:?}");
        // every member's inputs are earlier members or pre-group values
        for g in &o.fused {
            for (pos, &m) in g.nodes.iter().enumerate() {
                for &inp in &o.nodes[m].ins {
                    assert!(
                        inp < m,
                        "member {m} reads later node {inp} (group pos {pos})"
                    );
                }
            }
        }
    }

    #[test]
    fn consuming_a_scatter_value_is_rejected() {
        let h = 2;
        let mut p = Program::new("bad-sink", 1, h);
        let x = p.node(OpKind::Pull, vec![], h);
        let s = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let a = p.node(OpKind::Add, vec![x, s], h);
        let sc = p.node(OpKind::Scatter, vec![a], h);
        p.node(OpKind::Push, vec![sc], h); // reads the scatter "value"
        let e = p.optimize().unwrap_err().to_string();
        // validate() rejects this shape first (the push source can never
        // live downstream of scatter); the pipeline guards independently
        // ("produces none") so the storage invariant is locally enforced
        assert!(
            e.contains("not part of the scattered state")
                || e.contains("produces none"),
            "{e}"
        );
    }

    /// SoftmaxCols/Broadcast lower to `Step::RowOp`, never join a fused
    /// group, and the compiled path stays bitwise identical to the
    /// reference interpreter (including their VJPs).
    #[test]
    fn rowops_schedule_and_match_reference() {
        let h = 4;
        let mut p = Program::new("rowop", 1, h);
        let w = p.param("W", &[h, h]);
        let x = p.node(OpKind::Pull, vec![], h);
        let s = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let m = p.node(OpKind::MatMul { param: w }, vec![x], h);
        let a = p.node(OpKind::Add, vec![m, s], h);
        let sm = p.node(OpKind::SoftmaxCols, vec![a], h);
        let sl = p.node(OpKind::SliceCols { start: 0, len: 1 }, vec![sm], 1);
        let bc = p.node(OpKind::Broadcast, vec![sl], h);
        let o = p.node(OpKind::Mul, vec![bc, s], h);
        let b = p.node(OpKind::Add, vec![o, a], h);
        p.node(OpKind::Scatter, vec![b], h);
        p.node(OpKind::Push, vec![b], h);
        let opt = p.optimize().unwrap();
        let rowops = opt
            .steps
            .iter()
            .filter(|s| matches!(s, Step::RowOp { .. }))
            .count();
        assert_eq!(rowops, 2, "steps: {:?}", opt.steps);
        // a row op closes any open fused group: no group spans one
        for g in &opt.fused {
            for &member in &g.nodes {
                assert!(
                    !matches!(
                        opt.nodes[member].kind,
                        OpKind::SoftmaxCols | OpKind::Broadcast
                    ),
                    "row op fused: {:?}",
                    opt.fused
                );
            }
        }
        assert_row_equivalence(p, 42);
    }

    #[test]
    fn stats_survive_into_summary() {
        let o = programs::gru_program(8).optimize().unwrap();
        let s = o.summary();
        assert!(s.contains('→'), "{s}");
        assert!(o.stats.ops_after >= 1);
        assert_eq!(o.params.len(), 3);
        assert_eq!(o.meta.arity, 1);
    }
}
