//! Op-graph builders for the shipped cells. The three builtins (lstm,
//! treelstm, treefc) mirror, operator by operator, the jnp reference
//! implementations in `python/compile/kernels/ref.py` — the unfused
//! interpreter (exec::unfused) executes them against the `op_*` artifacts
//! and must agree numerically with the fused whole-cell artifact (tested
//! in engine_equivalence.rs). The host `Program` interpreter
//! (vertex::interp) evaluates the same graphs with no artifacts at all.
//!
//! `gru` and `cstreelstm` exist **only** as programs: no hand-written
//! kernel, no engine/serve special-casing — they are the proof that the
//! CellSpec API is open (DESIGN.md §8 walks through defining `gru`).
//!
//! Parameter indices refer to the `Program::param` declaration order,
//! which for the builtins mirrors aot.py's argument order:
//!   lstm:       0=W [h,4h]  1=U [h,4h]  2=b [4h]
//!   treelstm:   0=Wiou [h,3h] 1=Wf [h,h] 2=Uiou [h,3h] 3=Uf [h,h]
//!               4=biou [3h] 5=bf [h]
//!   treefc:     0=Wx 1=Wl 2=Wr [h,h]  3=b [h]
//!   gru:        0=W [h,3h]  1=U [h,3h]  2=b [3h]   (gates [r|z|n])
//!   cstreelstm: 0=W [h,4h]  1=U [h,4h]  2=b [4h]   (gates [i|f|o|u])

use super::{OpKind, Program};

/// Sequence LSTM cell (state = [c | h], 2h columns).
pub fn lstm_program(h: usize) -> Program {
    let mut p = Program::new("lstm", 1, 2 * h);
    let w = p.param("W", &[h, 4 * h]);
    let u = p.param("U", &[h, 4 * h]);
    let b = p.param("b", &[4 * h]);
    let x = p.node(OpKind::Pull, vec![], h);
    let s = p.node(OpKind::Gather { slot: 0 }, vec![], 2 * h);
    let cprev = p.node(OpKind::SliceCols { start: 0, len: h }, vec![s], h);
    let hprev = p.node(OpKind::SliceCols { start: h, len: h }, vec![s], h);
    let g1 = p.node(OpKind::MatMul { param: w }, vec![x], 4 * h);
    let g2 = p.node(OpKind::MatMul { param: u }, vec![hprev], 4 * h);
    let gsum = p.node(OpKind::Add, vec![g1, g2], 4 * h);
    let pre = p.node(OpKind::AddBias { param: b }, vec![gsum], 4 * h);
    let pi = p.node(OpKind::SliceCols { start: 0, len: h }, vec![pre], h);
    let pf = p.node(OpKind::SliceCols { start: h, len: h }, vec![pre], h);
    let po = p.node(OpKind::SliceCols { start: 2 * h, len: h }, vec![pre], h);
    let pu = p.node(OpKind::SliceCols { start: 3 * h, len: h }, vec![pre], h);
    let i = p.node(OpKind::Sigmoid, vec![pi], h);
    let f = p.node(OpKind::Sigmoid, vec![pf], h);
    let o = p.node(OpKind::Sigmoid, vec![po], h);
    let u2 = p.node(OpKind::Tanh, vec![pu], h);
    let fc = p.node(OpKind::Mul, vec![f, cprev], h);
    let iu = p.node(OpKind::Mul, vec![i, u2], h);
    let c2 = p.node(OpKind::Add, vec![fc, iu], h);
    let tc = p.node(OpKind::Tanh, vec![c2], h);
    let h2 = p.node(OpKind::Mul, vec![o, tc], h);
    let sout = p.node(OpKind::ConcatCols, vec![c2, h2], 2 * h);
    p.node(OpKind::Scatter, vec![sout], 2 * h);
    p.node(OpKind::Push, vec![h2], h);
    p
}

/// Binary child-sum Tree-LSTM cell (paper Fig. 4 / Fig. 7 with N=2),
/// per-child forget gates sharing Uf.
pub fn treelstm_program(h: usize) -> Program {
    let mut p = Program::new("treelstm", 2, 2 * h);
    let wiou = p.param("Wiou", &[h, 3 * h]);
    let wf = p.param("Wf", &[h, h]);
    let uiou = p.param("Uiou", &[h, 3 * h]);
    let uf = p.param("Uf", &[h, h]);
    let biou = p.param("biou", &[3 * h]);
    let bf = p.param("bf", &[h]);
    let x = p.node(OpKind::Pull, vec![], h);
    let s1 = p.node(OpKind::Gather { slot: 0 }, vec![], 2 * h);
    let s2 = p.node(OpKind::Gather { slot: 1 }, vec![], 2 * h);
    let c1 = p.node(OpKind::SliceCols { start: 0, len: h }, vec![s1], h);
    let h1 = p.node(OpKind::SliceCols { start: h, len: h }, vec![s1], h);
    let c2 = p.node(OpKind::SliceCols { start: 0, len: h }, vec![s2], h);
    let h2 = p.node(OpKind::SliceCols { start: h, len: h }, vec![s2], h);
    let hsum = p.node(OpKind::Add, vec![h1, h2], h);
    // iou path
    let giou_x = p.node(OpKind::MatMul { param: wiou }, vec![x], 3 * h);
    let giou_h = p.node(OpKind::MatMul { param: uiou }, vec![hsum], 3 * h);
    let giou_s = p.node(OpKind::Add, vec![giou_x, giou_h], 3 * h);
    let pre_iou = p.node(OpKind::AddBias { param: biou }, vec![giou_s], 3 * h);
    // forget paths (shared x @ Wf)
    let gf_x = p.node(OpKind::MatMul { param: wf }, vec![x], h);
    let gf1_h = p.node(OpKind::MatMul { param: uf }, vec![h1], h);
    let gf2_h = p.node(OpKind::MatMul { param: uf }, vec![h2], h);
    let gf1_s = p.node(OpKind::Add, vec![gf_x, gf1_h], h);
    let gf2_s = p.node(OpKind::Add, vec![gf_x, gf2_h], h);
    let pre_f1 = p.node(OpKind::AddBias { param: bf }, vec![gf1_s], h);
    let pre_f2 = p.node(OpKind::AddBias { param: bf }, vec![gf2_s], h);
    // gates
    let pi = p.node(OpKind::SliceCols { start: 0, len: h }, vec![pre_iou], h);
    let po = p.node(OpKind::SliceCols { start: h, len: h }, vec![pre_iou], h);
    let pu = p.node(OpKind::SliceCols { start: 2 * h, len: h }, vec![pre_iou], h);
    let i = p.node(OpKind::Sigmoid, vec![pi], h);
    let o = p.node(OpKind::Sigmoid, vec![po], h);
    let u = p.node(OpKind::Tanh, vec![pu], h);
    let f1 = p.node(OpKind::Sigmoid, vec![pre_f1], h);
    let f2 = p.node(OpKind::Sigmoid, vec![pre_f2], h);
    let iu = p.node(OpKind::Mul, vec![i, u], h);
    let f1c = p.node(OpKind::Mul, vec![f1, c1], h);
    let f2c = p.node(OpKind::Mul, vec![f2, c2], h);
    let cp = p.node(OpKind::Add, vec![iu, f1c], h);
    let cnew = p.node(OpKind::Add, vec![cp, f2c], h);
    let tc = p.node(OpKind::Tanh, vec![cnew], h);
    let hnew = p.node(OpKind::Mul, vec![o, tc], h);
    let sout = p.node(OpKind::ConcatCols, vec![cnew, hnew], 2 * h);
    p.node(OpKind::Scatter, vec![sout], 2 * h);
    p.node(OpKind::Push, vec![hnew], h);
    p
}

/// Tree-FC cell (Fold benchmark): h' = tanh(x Wx + h1 Wl + h2 Wr + b).
pub fn treefc_program(h: usize) -> Program {
    let mut p = Program::new("treefc", 2, h);
    let wx = p.param("Wx", &[h, h]);
    let wl = p.param("Wl", &[h, h]);
    let wr = p.param("Wr", &[h, h]);
    let b = p.param("b", &[h]);
    let x = p.node(OpKind::Pull, vec![], h);
    let h1 = p.node(OpKind::Gather { slot: 0 }, vec![], h);
    let h2 = p.node(OpKind::Gather { slot: 1 }, vec![], h);
    let gx = p.node(OpKind::MatMul { param: wx }, vec![x], h);
    let gl = p.node(OpKind::MatMul { param: wl }, vec![h1], h);
    let gr = p.node(OpKind::MatMul { param: wr }, vec![h2], h);
    let s1 = p.node(OpKind::Add, vec![gx, gl], h);
    let s2 = p.node(OpKind::Add, vec![s1, gr], h);
    let pre = p.node(OpKind::AddBias { param: b }, vec![s2], h);
    let out = p.node(OpKind::Tanh, vec![pre], h);
    p.node(OpKind::Scatter, vec![out], h);
    p.node(OpKind::Push, vec![out], h);
    p
}

/// GRU sequence cell (state = h), gates packed `[r | z | n]`:
///
/// ```text
/// r = σ(xW_r + hU_r + b_r)        n = tanh(xW_n + b_n + r ⊙ hU_n)
/// z = σ(xW_z + hU_z + b_z)        h' = (1-z) ⊙ n + z ⊙ h
/// ```
///
/// Defined **only** as a program — the engine, serve, and training layers
/// run it through the generic CellSpec machinery with zero cell-specific
/// code (DESIGN.md §8 uses this builder as the worked example).
pub fn gru_program(h: usize) -> Program {
    let mut p = Program::new("gru", 1, h);
    let w = p.param("W", &[h, 3 * h]);
    let u = p.param("U", &[h, 3 * h]);
    let b = p.param("b", &[3 * h]);
    let x = p.node(OpKind::Pull, vec![], h);
    let hp = p.node(OpKind::Gather { slot: 0 }, vec![], h);
    let gx = p.node(OpKind::MatMul { param: w }, vec![x], 3 * h);
    let gh = p.node(OpKind::MatMul { param: u }, vec![hp], 3 * h);
    let gxb = p.node(OpKind::AddBias { param: b }, vec![gx], 3 * h);
    let xr = p.node(OpKind::SliceCols { start: 0, len: h }, vec![gxb], h);
    let xz = p.node(OpKind::SliceCols { start: h, len: h }, vec![gxb], h);
    let xn = p.node(OpKind::SliceCols { start: 2 * h, len: h }, vec![gxb], h);
    let hr = p.node(OpKind::SliceCols { start: 0, len: h }, vec![gh], h);
    let hz = p.node(OpKind::SliceCols { start: h, len: h }, vec![gh], h);
    let hn = p.node(OpKind::SliceCols { start: 2 * h, len: h }, vec![gh], h);
    let ar = p.node(OpKind::Add, vec![xr, hr], h);
    let r = p.node(OpKind::Sigmoid, vec![ar], h);
    let az = p.node(OpKind::Add, vec![xz, hz], h);
    let z = p.node(OpKind::Sigmoid, vec![az], h);
    let rhn = p.node(OpKind::Mul, vec![r, hn], h);
    let an = p.node(OpKind::Add, vec![xn, rhn], h);
    let n = p.node(OpKind::Tanh, vec![an], h);
    let zc = p.node(OpKind::OneMinus, vec![z], h);
    let zn = p.node(OpKind::Mul, vec![zc, n], h);
    let zh = p.node(OpKind::Mul, vec![z, hp], h);
    let hnew = p.node(OpKind::Add, vec![zn, zh], h);
    p.node(OpKind::Scatter, vec![hnew], h);
    p.node(OpKind::Push, vec![hnew], h);
    p
}

/// Child-sum Tree-LSTM with a tied forget gate (state = [c | h]): the iou
/// gates and a single forget gate are computed from the *summed* child
/// state `h̃ = h1 + h2`, and the forget gate multiplies the summed cell
/// `c̃ = c1 + c2` (Tai et al. 2015, the tied-forget simplification):
///
/// ```text
/// [i|f|o|u] = xW + h̃U + b
/// c' = σ(f) ⊙ c̃ + σ(i) ⊙ tanh(u)      h' = σ(o) ⊙ tanh(c')
/// ```
///
/// Like `gru`, this cell is defined **only** as a program; it is distinct
/// from the builtin `treelstm` (per-child forget gates, separate Wf/Uf).
pub fn cstreelstm_program(h: usize) -> Program {
    let mut p = Program::new("cstreelstm", 2, 2 * h);
    let w = p.param("W", &[h, 4 * h]);
    let u = p.param("U", &[h, 4 * h]);
    let b = p.param("b", &[4 * h]);
    let x = p.node(OpKind::Pull, vec![], h);
    let s1 = p.node(OpKind::Gather { slot: 0 }, vec![], 2 * h);
    let s2 = p.node(OpKind::Gather { slot: 1 }, vec![], 2 * h);
    let c1 = p.node(OpKind::SliceCols { start: 0, len: h }, vec![s1], h);
    let h1 = p.node(OpKind::SliceCols { start: h, len: h }, vec![s1], h);
    let c2 = p.node(OpKind::SliceCols { start: 0, len: h }, vec![s2], h);
    let h2 = p.node(OpKind::SliceCols { start: h, len: h }, vec![s2], h);
    let hsum = p.node(OpKind::Add, vec![h1, h2], h);
    let csum = p.node(OpKind::Add, vec![c1, c2], h);
    let g1 = p.node(OpKind::MatMul { param: w }, vec![x], 4 * h);
    let g2 = p.node(OpKind::MatMul { param: u }, vec![hsum], 4 * h);
    let gsum = p.node(OpKind::Add, vec![g1, g2], 4 * h);
    let pre = p.node(OpKind::AddBias { param: b }, vec![gsum], 4 * h);
    let pi = p.node(OpKind::SliceCols { start: 0, len: h }, vec![pre], h);
    let pf = p.node(OpKind::SliceCols { start: h, len: h }, vec![pre], h);
    let po = p.node(OpKind::SliceCols { start: 2 * h, len: h }, vec![pre], h);
    let pu = p.node(OpKind::SliceCols { start: 3 * h, len: h }, vec![pre], h);
    let i = p.node(OpKind::Sigmoid, vec![pi], h);
    let f = p.node(OpKind::Sigmoid, vec![pf], h);
    let o = p.node(OpKind::Sigmoid, vec![po], h);
    let uu = p.node(OpKind::Tanh, vec![pu], h);
    let fc = p.node(OpKind::Mul, vec![f, csum], h);
    let iu = p.node(OpKind::Mul, vec![i, uu], h);
    let cnew = p.node(OpKind::Add, vec![fc, iu], h);
    let tc = p.node(OpKind::Tanh, vec![cnew], h);
    let hnew = p.node(OpKind::Mul, vec![o, tc], h);
    let sout = p.node(OpKind::ConcatCols, vec![cnew, hnew], 2 * h);
    p.node(OpKind::Scatter, vec![sout], 2 * h);
    p.node(OpKind::Push, vec![hnew], h);
    p
}

/// Neighbourhood slots of the message-passing GNN cell ([`gnn_program`]).
pub const GNN_FANIN: usize = 4;

/// GNN message-passing cell over general DAGs (state = h):
///
/// ```text
/// m  = Σ_k s_k                    (sum over up to 4 child/neighbour slots;
///                                  absent neighbours gather zeros)
/// h' = tanh(x Wx + m Wn + b)
/// ```
///
/// The aggregate-then-transform step of a GCN/GraphSAGE-style layer,
/// phrased purely as a Program: multi-parent fan-in comes from the input
/// DAG (one vertex's state gathered by several parents; the backward
/// accumulates their adjoints through the scatter-add duality), not from
/// any new executor machinery. Defined **only** as a program, like `gru`.
pub fn gnn_program(h: usize) -> Program {
    let mut p = Program::new("gnn", GNN_FANIN, h);
    let wx = p.param("Wx", &[h, h]);
    let wn = p.param("Wn", &[h, h]);
    let b = p.param("b", &[h]);
    let x = p.node(OpKind::Pull, vec![], h);
    let mut msum: Option<usize> = None;
    for k in 0..GNN_FANIN {
        let s = p.node(OpKind::Gather { slot: k }, vec![], h);
        msum = Some(match msum {
            None => s,
            Some(m) => p.node(OpKind::Add, vec![m, s], h),
        });
    }
    let m = msum.expect("GNN_FANIN >= 1");
    let gx = p.node(OpKind::MatMul { param: wx }, vec![x], h);
    let gm = p.node(OpKind::MatMul { param: wn }, vec![m], h);
    let sum = p.node(OpKind::Add, vec![gx, gm], h);
    let pre = p.node(OpKind::AddBias { param: b }, vec![sum], h);
    let out = p.node(OpKind::Tanh, vec![pre], h);
    p.node(OpKind::Scatter, vec![out], h);
    p.node(OpKind::Push, vec![out], h);
    p
}

/// Encoder-memory slots of the attention cell ([`attnseq2seq_program`]):
/// slot 0 is the recurrent predecessor, slots `1..=ATTN_MEM` attend over
/// encoder states.
pub const ATTN_MEM: usize = 3;

/// Attention-bearing seq2seq cell (state = h). Slot 0 gathers the
/// recurrent predecessor, slots 1..=3 gather encoder memory rows the
/// decoder attends over (multiplicative attention, then a Tree-FC-style
/// combine):
///
/// ```text
/// q   = tanh(x Wq + s₀ Uq)                       (query)
/// eₖ  = (q ⊙ mₖ) Wa                              (score per memory slot)
/// α   = softmax(e₁ … e₃)                         (SoftmaxCols)
/// ctx = Σₖ αₖ · mₖ                               (Broadcast + Mul + Add)
/// h'  = tanh(x W + s₀ U + ctx C + b)
/// ```
///
/// Encoder vertices simply have no memory children: their slots gather
/// zeros, the softmax degenerates to uniform weights over zero rows, and
/// `ctx = 0` — the cell reduces to a plain recurrent unit. Decoder
/// vertices wire every memory slot at the same encoder states, making the
/// instance graph a true DAG (each encoder state fans into every decoder
/// step). Defined **only** as a program.
pub fn attnseq2seq_program(h: usize) -> Program {
    let mut p = Program::new("attnseq2seq", 1 + ATTN_MEM, h);
    let wq = p.param("Wq", &[h, h]);
    let uq = p.param("Uq", &[h, h]);
    let wa = p.param("Wa", &[h, 1]);
    let w = p.param("W", &[h, h]);
    let u = p.param("U", &[h, h]);
    let c = p.param("C", &[h, h]);
    let b = p.param("b", &[h]);
    let x = p.node(OpKind::Pull, vec![], h);
    let hp = p.node(OpKind::Gather { slot: 0 }, vec![], h);
    let mems: Vec<usize> = (0..ATTN_MEM)
        .map(|k| p.node(OpKind::Gather { slot: 1 + k }, vec![], h))
        .collect();
    // query
    let qx = p.node(OpKind::MatMul { param: wq }, vec![x], h);
    let qh = p.node(OpKind::MatMul { param: uq }, vec![hp], h);
    let qs = p.node(OpKind::Add, vec![qx, qh], h);
    let q = p.node(OpKind::Tanh, vec![qs], h);
    // per-slot multiplicative scores -> row softmax
    let scores: Vec<usize> = mems
        .iter()
        .map(|&m| {
            let qm = p.node(OpKind::Mul, vec![q, m], h);
            p.node(OpKind::MatMul { param: wa }, vec![qm], 1)
        })
        .collect();
    let sc = p.node(OpKind::ConcatCols, scores, ATTN_MEM);
    let alpha = p.node(OpKind::SoftmaxCols, vec![sc], ATTN_MEM);
    // context = Σₖ αₖ · mₖ
    let mut ctx: Option<usize> = None;
    for (k, &m) in mems.iter().enumerate() {
        let ak = p.node(OpKind::SliceCols { start: k, len: 1 }, vec![alpha], 1);
        let bk = p.node(OpKind::Broadcast, vec![ak], h);
        let wm = p.node(OpKind::Mul, vec![bk, m], h);
        ctx = Some(match ctx {
            None => wm,
            Some(acc) => p.node(OpKind::Add, vec![acc, wm], h),
        });
    }
    let ctx = ctx.expect("ATTN_MEM >= 1");
    // combine
    let gx = p.node(OpKind::MatMul { param: w }, vec![x], h);
    let gh = p.node(OpKind::MatMul { param: u }, vec![hp], h);
    let gc = p.node(OpKind::MatMul { param: c }, vec![ctx], h);
    let s1 = p.node(OpKind::Add, vec![gx, gh], h);
    let s2 = p.node(OpKind::Add, vec![s1, gc], h);
    let pre = p.node(OpKind::AddBias { param: b }, vec![s2], h);
    let out = p.node(OpKind::Tanh, vec![pre], h);
    p.node(OpKind::Scatter, vec![out], h);
    p.node(OpKind::Push, vec![out], h);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn programs_are_topological() {
        for p in [
            lstm_program(4),
            treelstm_program(4),
            treefc_program(4),
            gru_program(4),
            cstreelstm_program(4),
        ] {
            for (i, n) in p.nodes.iter().enumerate() {
                for &j in &n.ins {
                    assert!(j < i, "{}: node {i} uses later node {j}", p.name);
                }
            }
        }
    }

    #[test]
    fn state_cols_match_scatter() {
        for p in [
            lstm_program(8),
            treelstm_program(8),
            treefc_program(8),
            gru_program(8),
            cstreelstm_program(8),
        ] {
            let scat = p
                .nodes
                .iter()
                .find(|n| matches!(n.kind, OpKind::Scatter))
                .unwrap();
            assert_eq!(scat.cols, p.state_cols);
        }
    }

    #[test]
    fn child_slots_cover_arity() {
        for p in [treelstm_program(4), cstreelstm_program(4)] {
            let slots: Vec<usize> = p
                .nodes
                .iter()
                .filter_map(|n| match n.kind {
                    OpKind::Gather { slot } => Some(slot),
                    _ => None,
                })
                .collect();
            assert_eq!(slots, vec![0, 1], "{}", p.name);
        }
    }

    #[test]
    fn gnn_and_attnseq2seq_validate_and_shape() {
        for h in [2, 8] {
            let g = gnn_program(h);
            let meta = g.validate().unwrap();
            assert_eq!(meta.arity, GNN_FANIN);
            assert_eq!(meta.state_cols, h);
            assert_eq!(meta.x_cols, h);

            let a = attnseq2seq_program(h);
            let meta = a.validate().unwrap();
            assert_eq!(meta.arity, 1 + ATTN_MEM);
            assert_eq!(meta.state_cols, h);
            // the attention chain really uses the new row-local ops
            assert!(a.nodes.iter().any(|n| matches!(n.kind, OpKind::SoftmaxCols)));
            assert_eq!(
                a.nodes
                    .iter()
                    .filter(|n| matches!(n.kind, OpKind::Broadcast))
                    .count(),
                ATTN_MEM
            );
            // both compile through the full pass pipeline + layout verify
            g.optimize().unwrap();
            a.optimize().unwrap();
        }
    }

    #[test]
    fn param_declarations_match_use() {
        // every program validates, so MatMul/AddBias shapes line up with
        // the declared ParamSpecs by construction
        for p in [gru_program(6), cstreelstm_program(6)] {
            p.validate().unwrap();
            assert_eq!(p.params.len(), 3);
            assert_eq!(p.params[0].name, "W");
            assert_eq!(p.params[2].name, "b");
        }
    }
}
