//! Op-graph builders for the paper's cells. These mirror, operator by
//! operator, the jnp reference implementations in
//! `python/compile/kernels/ref.py` — the unfused interpreter (exec::unfused)
//! executes them against the `op_*` artifacts and must agree numerically
//! with the fused whole-cell artifact (tested in engine_equivalence.rs).
//!
//! Parameter indices refer to the model's parameter order:
//!   lstm:     0=W [h,4h]  1=U [h,4h]  2=b [4h]
//!   treelstm: 0=Wiou [h,3h] 1=Wf [h,h] 2=Uiou [h,3h] 3=Uf [h,h]
//!             4=biou [3h] 5=bf [h]
//!   treefc:   0=Wx 1=Wl 2=Wr [h,h]  3=b [h]

use super::{OpKind, Program};

/// Sequence LSTM cell (state = [c | h], 2h columns).
pub fn lstm_program(h: usize) -> Program {
    let mut p = Program {
        name: "lstm".into(),
        nodes: Vec::new(),
        n_children: 1,
        state_cols: 2 * h,
    };
    let x = p.node(OpKind::Pull, vec![], h);
    let s = p.node(OpKind::Gather { slot: 0 }, vec![], 2 * h);
    let cprev = p.node(OpKind::SliceCols { start: 0, len: h }, vec![s], h);
    let hprev = p.node(OpKind::SliceCols { start: h, len: h }, vec![s], h);
    let g1 = p.node(OpKind::MatMul { param: 0 }, vec![x], 4 * h);
    let g2 = p.node(OpKind::MatMul { param: 1 }, vec![hprev], 4 * h);
    let gsum = p.node(OpKind::Add, vec![g1, g2], 4 * h);
    let pre = p.node(OpKind::AddBias { param: 2 }, vec![gsum], 4 * h);
    let pi = p.node(OpKind::SliceCols { start: 0, len: h }, vec![pre], h);
    let pf = p.node(OpKind::SliceCols { start: h, len: h }, vec![pre], h);
    let po = p.node(OpKind::SliceCols { start: 2 * h, len: h }, vec![pre], h);
    let pu = p.node(OpKind::SliceCols { start: 3 * h, len: h }, vec![pre], h);
    let i = p.node(OpKind::Sigmoid, vec![pi], h);
    let f = p.node(OpKind::Sigmoid, vec![pf], h);
    let o = p.node(OpKind::Sigmoid, vec![po], h);
    let u = p.node(OpKind::Tanh, vec![pu], h);
    let fc = p.node(OpKind::Mul, vec![f, cprev], h);
    let iu = p.node(OpKind::Mul, vec![i, u], h);
    let c2 = p.node(OpKind::Add, vec![fc, iu], h);
    let tc = p.node(OpKind::Tanh, vec![c2], h);
    let h2 = p.node(OpKind::Mul, vec![o, tc], h);
    let sout = p.node(OpKind::ConcatCols, vec![c2, h2], 2 * h);
    p.node(OpKind::Scatter, vec![sout], 2 * h);
    p.node(OpKind::Push, vec![h2], h);
    p
}

/// Binary child-sum Tree-LSTM cell (paper Fig. 4 / Fig. 7 with N=2).
pub fn treelstm_program(h: usize) -> Program {
    let mut p = Program {
        name: "treelstm".into(),
        nodes: Vec::new(),
        n_children: 2,
        state_cols: 2 * h,
    };
    let x = p.node(OpKind::Pull, vec![], h);
    let s1 = p.node(OpKind::Gather { slot: 0 }, vec![], 2 * h);
    let s2 = p.node(OpKind::Gather { slot: 1 }, vec![], 2 * h);
    let c1 = p.node(OpKind::SliceCols { start: 0, len: h }, vec![s1], h);
    let h1 = p.node(OpKind::SliceCols { start: h, len: h }, vec![s1], h);
    let c2 = p.node(OpKind::SliceCols { start: 0, len: h }, vec![s2], h);
    let h2 = p.node(OpKind::SliceCols { start: h, len: h }, vec![s2], h);
    let hsum = p.node(OpKind::Add, vec![h1, h2], h);
    // iou path
    let giou_x = p.node(OpKind::MatMul { param: 0 }, vec![x], 3 * h);
    let giou_h = p.node(OpKind::MatMul { param: 2 }, vec![hsum], 3 * h);
    let giou_s = p.node(OpKind::Add, vec![giou_x, giou_h], 3 * h);
    let pre_iou = p.node(OpKind::AddBias { param: 4 }, vec![giou_s], 3 * h);
    // forget paths (shared x @ Wf)
    let gf_x = p.node(OpKind::MatMul { param: 1 }, vec![x], h);
    let gf1_h = p.node(OpKind::MatMul { param: 3 }, vec![h1], h);
    let gf2_h = p.node(OpKind::MatMul { param: 3 }, vec![h2], h);
    let gf1_s = p.node(OpKind::Add, vec![gf_x, gf1_h], h);
    let gf2_s = p.node(OpKind::Add, vec![gf_x, gf2_h], h);
    let pre_f1 = p.node(OpKind::AddBias { param: 5 }, vec![gf1_s], h);
    let pre_f2 = p.node(OpKind::AddBias { param: 5 }, vec![gf2_s], h);
    // gates
    let pi = p.node(OpKind::SliceCols { start: 0, len: h }, vec![pre_iou], h);
    let po = p.node(OpKind::SliceCols { start: h, len: h }, vec![pre_iou], h);
    let pu = p.node(OpKind::SliceCols { start: 2 * h, len: h }, vec![pre_iou], h);
    let i = p.node(OpKind::Sigmoid, vec![pi], h);
    let o = p.node(OpKind::Sigmoid, vec![po], h);
    let u = p.node(OpKind::Tanh, vec![pu], h);
    let f1 = p.node(OpKind::Sigmoid, vec![pre_f1], h);
    let f2 = p.node(OpKind::Sigmoid, vec![pre_f2], h);
    let iu = p.node(OpKind::Mul, vec![i, u], h);
    let f1c = p.node(OpKind::Mul, vec![f1, c1], h);
    let f2c = p.node(OpKind::Mul, vec![f2, c2], h);
    let cp = p.node(OpKind::Add, vec![iu, f1c], h);
    let cnew = p.node(OpKind::Add, vec![cp, f2c], h);
    let tc = p.node(OpKind::Tanh, vec![cnew], h);
    let hnew = p.node(OpKind::Mul, vec![o, tc], h);
    let sout = p.node(OpKind::ConcatCols, vec![cnew, hnew], 2 * h);
    p.node(OpKind::Scatter, vec![sout], 2 * h);
    p.node(OpKind::Push, vec![hnew], h);
    p
}

/// Tree-FC cell (Fold benchmark): h' = tanh(x Wx + h1 Wl + h2 Wr + b).
pub fn treefc_program(h: usize) -> Program {
    let mut p = Program {
        name: "treefc".into(),
        nodes: Vec::new(),
        n_children: 2,
        state_cols: h,
    };
    let x = p.node(OpKind::Pull, vec![], h);
    let h1 = p.node(OpKind::Gather { slot: 0 }, vec![], h);
    let h2 = p.node(OpKind::Gather { slot: 1 }, vec![], h);
    let gx = p.node(OpKind::MatMul { param: 0 }, vec![x], h);
    let gl = p.node(OpKind::MatMul { param: 1 }, vec![h1], h);
    let gr = p.node(OpKind::MatMul { param: 2 }, vec![h2], h);
    let s1 = p.node(OpKind::Add, vec![gx, gl], h);
    let s2 = p.node(OpKind::Add, vec![s1, gr], h);
    let pre = p.node(OpKind::AddBias { param: 3 }, vec![s2], h);
    let out = p.node(OpKind::Tanh, vec![pre], h);
    p.node(OpKind::Scatter, vec![out], h);
    p.node(OpKind::Push, vec![out], h);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn programs_are_topological() {
        for p in [lstm_program(4), treelstm_program(4), treefc_program(4)] {
            for (i, n) in p.nodes.iter().enumerate() {
                for &j in &n.ins {
                    assert!(j < i, "{}: node {i} uses later node {j}", p.name);
                }
            }
        }
    }

    #[test]
    fn state_cols_match_scatter() {
        for p in [lstm_program(8), treelstm_program(8), treefc_program(8)] {
            let scat = p
                .nodes
                .iter()
                .find(|n| matches!(n.kind, OpKind::Scatter))
                .unwrap();
            assert_eq!(scat.cols, p.state_cols);
        }
    }

    #[test]
    fn child_slots_cover_arity() {
        let p = treelstm_program(4);
        let slots: Vec<usize> = p
            .nodes
            .iter()
            .filter_map(|n| match n.kind {
                OpKind::Gather { slot } => Some(slot),
                _ => None,
            })
            .collect();
        assert_eq!(slots, vec![0, 1]);
    }
}
