//! The CellSpec registry: cell names → program builders.
//!
//! This is the open end of the API (paper §3.1: users *write* F; the
//! system derives scheduling, batching and backpropagation from it).
//! Builtins are seeded at first use:
//!
//! | name         | arity | definition                                  |
//! |--------------|-------|---------------------------------------------|
//! | `lstm`       | 1     | program + fused/op artifacts (aot.py)       |
//! | `treelstm`   | 2     | program + fused/op artifacts (aot.py)       |
//! | `treefc`     | 2     | program + fused/op artifacts (aot.py)       |
//! | `gru`        | 1     | **program only** (DESIGN.md §8 walkthrough) |
//! | `cstreelstm` | 2     | **program only** (tied-forget child-sum)    |
//! | `gnn`        | 4     | **program only** (DAG message passing)      |
//! | `attnseq2seq`| 4     | **program only** (attention decoder)        |
//!
//! User cells are added with [`register_cell`]; the builder is probed and
//! [`Program::validate`]d at registration, so a malformed cell fails
//! *here* with a proper error, never inside a minibatch. A registered
//! cell immediately works everywhere a builtin does: `cavs train` /
//! `eval` / `serve` / `bench` / `analyze` / `cells`, the host training
//! driver, and the PJRT engine (given artifacts compiled under the same
//! name).

use std::collections::BTreeMap;
use std::sync::{Arc, LazyLock, RwLock};

use anyhow::{bail, Context, Result};

use super::interp::ProgramCell;
use super::opt::{OptProgram, OptStats};
use super::{programs, ParamSpec, Program, ProgramMeta};
use crate::exec::kernels::MathMode;
use crate::util::rng::Rng;

type Builder = Arc<dyn Fn(usize) -> Program + Send + Sync>;

struct Entry {
    build: Builder,
    /// aot.py emits per-operator (`op_*`) artifacts for this cell, so the
    /// engine's `fusion=false` ablation can interpret it op-by-op on PJRT
    unfused_ops: bool,
    builtin: bool,
}

static REGISTRY: LazyLock<RwLock<BTreeMap<String, Entry>>> = LazyLock::new(|| {
    let mut m = BTreeMap::new();
    let builtin = |f: fn(usize) -> Program, unfused_ops: bool| Entry {
        build: Arc::new(f),
        unfused_ops,
        builtin: true,
    };
    m.insert("lstm".to_string(), builtin(programs::lstm_program, true));
    m.insert("treelstm".to_string(), builtin(programs::treelstm_program, true));
    m.insert("treefc".to_string(), builtin(programs::treefc_program, true));
    m.insert("gru".to_string(), builtin(programs::gru_program, false));
    m.insert(
        "cstreelstm".to_string(),
        builtin(programs::cstreelstm_program, false),
    );
    m.insert("gnn".to_string(), builtin(programs::gnn_program, false));
    m.insert(
        "attnseq2seq".to_string(),
        builtin(programs::attnseq2seq_program, false),
    );
    RwLock::new(m)
});

/// Register a user-defined cell. The builder maps a hidden size `h` to a
/// [`Program`]; it is probed at two sizes and validated immediately, so
/// malformed programs are rejected at registration. Errors on duplicate
/// names (builtins cannot be shadowed).
pub fn register_cell(
    name: &str,
    build: impl Fn(usize) -> Program + Send + Sync + 'static,
) -> Result<()> {
    if name.is_empty() || name.chars().any(|c| c.is_whitespace() || c == '_') {
        bail!(
            "cell name '{name}' must be non-empty, without whitespace or '_' \
             (artifact names use '_' as a separator)"
        );
    }
    for h in [2usize, 8] {
        let p = build(h);
        p.validate()
            .with_context(|| format!("registering cell '{name}' (probe h={h})"))?;
        // the optimizer runs at every CellSpec lookup, so a program the
        // pass pipeline rejects must fail here, not inside a minibatch
        p.optimize().with_context(|| {
            format!("registering cell '{name}' (optimizer probe h={h})")
        })?;
    }
    let mut reg = REGISTRY.write().unwrap();
    if reg.contains_key(name) {
        bail!("cell '{name}' is already registered");
    }
    reg.insert(
        name.to_string(),
        Entry { build: Arc::new(build), unfused_ops: false, builtin: false },
    );
    Ok(())
}

/// All registered cell names (builtins + user cells), sorted.
pub fn registered_cells() -> Vec<String> {
    REGISTRY.read().unwrap().keys().cloned().collect()
}

pub fn is_registered(name: &str) -> bool {
    REGISTRY.read().unwrap().contains_key(name)
}

struct CellInfo {
    name: String,
    h: usize,
    program: Program,
    meta: ProgramMeta,
    /// the compiled plan, built once at spec construction ("optimize at
    /// registration") and shared by every cell instantiated from it
    opt: Arc<OptProgram>,
    unfused_ops: bool,
    builtin: bool,
}

/// A registered cell instantiated at a hidden size: the program plus its
/// derived metadata, cheap to clone (one `Arc`). This is what `Model`
/// carries and every layer dispatches on — the `Cell` enum survives only
/// as a thin alias for the three artifact-backed builtin names.
#[derive(Clone)]
pub struct CellSpec(Arc<CellInfo>);

impl std::fmt::Debug for CellSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CellSpec")
            .field("name", &self.0.name)
            .field("h", &self.0.h)
            .field("meta", &self.0.meta)
            .finish()
    }
}

impl CellSpec {
    /// Instantiate a registered cell at hidden size `h`.
    pub fn lookup(name: &str, h: usize) -> Result<CellSpec> {
        let (program, unfused_ops, builtin) = {
            let reg = REGISTRY.read().unwrap();
            let e = reg.get(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown cell '{name}' (registered: {})",
                    registered_list(&reg)
                )
            })?;
            ((e.build)(h), e.unfused_ops, e.builtin)
        };
        CellSpec::build(program, h, unfused_ops, builtin)
    }

    /// Wrap an ad-hoc (unregistered) program as a spec — handy for tests
    /// and one-off experiments; registered cells should prefer
    /// [`register_cell`] + [`CellSpec::lookup`].
    pub fn from_program(program: Program, h: usize) -> Result<CellSpec> {
        CellSpec::build(program, h, false, false)
    }

    fn build(
        program: Program,
        h: usize,
        unfused_ops: bool,
        builtin: bool,
    ) -> Result<CellSpec> {
        let meta = program
            .validate()
            .with_context(|| format!("cell '{}' at h={h}", program.name))?;
        let opt = Arc::new(
            program
                .optimize()
                .with_context(|| format!("optimizing cell '{}' at h={h}", program.name))?,
        );
        Ok(CellSpec(Arc::new(CellInfo {
            name: program.name.clone(),
            h,
            program,
            meta,
            opt,
            unfused_ops,
            builtin,
        })))
    }

    pub fn name(&self) -> &str {
        &self.0.name
    }

    /// The hidden size this spec was instantiated at (artifact names and
    /// embedding dims are keyed by it).
    pub fn h(&self) -> usize {
        self.0.h
    }

    /// The authoritative description of F.
    pub fn program(&self) -> &Program {
        &self.0.program
    }

    pub fn meta(&self) -> &ProgramMeta {
        &self.0.meta
    }

    /// Child slots the cell consumes (gather arity).
    pub fn arity(&self) -> usize {
        self.0.meta.arity
    }

    /// Columns of the scattered state.
    pub fn state_cols(&self) -> usize {
        self.0.meta.state_cols
    }

    /// Columns of the pull input `x` (the embedding dimension).
    pub fn x_cols(&self) -> usize {
        self.0.meta.x_cols
    }

    /// Column offset/width of the state slice that heads read.
    pub fn h_part(&self) -> (usize, usize) {
        (self.0.meta.h_off, self.0.meta.h_len)
    }

    /// Gate-preactivation columns emitted by bwd_data (lazy batching).
    pub fn gates_cols(&self) -> usize {
        self.0.meta.gates_cols
    }

    /// Named parameter (name, shape) list, program declaration order
    /// (mirrors aot.py's argument order for the builtins).
    pub fn param_shapes(&self) -> &[ParamSpec] {
        &self.0.program.params
    }

    /// Whether aot.py emits per-operator artifacts for the `fusion=false`
    /// ablation (builtin cells only).
    pub fn has_unfused_ops(&self) -> bool {
        self.0.unfused_ops
    }

    /// Whether this is one of the seeded builtin cells.
    pub fn is_builtin(&self) -> bool {
        self.0.builtin
    }

    /// The compiled form of the program (pass-pipeline output), shared by
    /// every cell instantiated from this spec.
    pub fn opt_program(&self) -> &OptProgram {
        &self.0.opt
    }

    /// What the pass pipeline did to this cell (op counts before/after,
    /// per-pass counters) — `cavs cells` prints this.
    pub fn opt_stats(&self) -> &OptStats {
        &self.0.opt.stats
    }

    /// Bind the program to host parameter tensors as an interpretable
    /// [`HostCell`](crate::exec::parallel::HostCell) executing through
    /// the cached compiled plan (the default host path).
    pub fn instantiate(&self, params: Vec<Vec<f32>>) -> Result<ProgramCell> {
        ProgramCell::with_plan(
            self.0.program.clone(),
            Arc::clone(&self.0.opt),
            params,
        )
    }

    /// Bind to the **reference** per-row interpreter (the `no_opt`
    /// escape hatch; bitwise identical, just slower).
    pub fn instantiate_unoptimized(&self, params: Vec<Vec<f32>>) -> Result<ProgramCell> {
        ProgramCell::new(self.0.program.clone(), params)
    }

    /// Bind the program to Gaussian-initialized parameters (optimized).
    pub fn random_cell(&self, rng: &mut Rng, scale: f32) -> Result<ProgramCell> {
        let params = super::interp::random_params(&self.0.program, rng, scale);
        self.instantiate(params)
    }

    /// Gaussian-initialized **reference** (unoptimized) cell — draws the
    /// same parameter stream as [`CellSpec::random_cell`], so the two are
    /// directly comparable.
    pub fn random_cell_unoptimized(&self, rng: &mut Rng, scale: f32) -> Result<ProgramCell> {
        ProgramCell::random(self.0.program.clone(), rng, scale)
    }

    /// [`CellSpec::instantiate`] with an explicit [`MathMode`] for the
    /// compiled path's kernel table (`Exact` is the plain `instantiate`).
    pub fn instantiate_math(
        &self,
        params: Vec<Vec<f32>>,
        math: MathMode,
    ) -> Result<ProgramCell> {
        let mut cell = self.instantiate(params)?;
        cell.set_math(math);
        Ok(cell)
    }

    /// [`CellSpec::random_cell`] with an explicit [`MathMode`] — the same
    /// parameter stream, so exact and fast cells are directly comparable.
    pub fn random_cell_math(
        &self,
        rng: &mut Rng,
        scale: f32,
        math: MathMode,
    ) -> Result<ProgramCell> {
        let mut cell = self.random_cell(rng, scale)?;
        cell.set_math(math);
        Ok(cell)
    }
}

fn registered_list(reg: &BTreeMap<String, Entry>) -> String {
    reg.keys().cloned().collect::<Vec<_>>().join(", ")
}

#[cfg(test)]
mod tests {
    use super::super::OpKind;
    use super::*;

    #[test]
    fn builtins_are_seeded_and_derivable() {
        for name in [
            "lstm",
            "treelstm",
            "treefc",
            "gru",
            "cstreelstm",
            "gnn",
            "attnseq2seq",
        ] {
            assert!(is_registered(name), "{name} missing");
            let spec = CellSpec::lookup(name, 8).unwrap();
            assert_eq!(spec.name(), name);
            assert_eq!(spec.h(), 8);
            assert_eq!(spec.x_cols(), 8);
            let (off, len) = spec.h_part();
            assert!(off + len <= spec.state_cols());
            assert!(!spec.param_shapes().is_empty());
        }
        assert!(CellSpec::lookup("bogus", 8).is_err());
        // the three artifact-backed builtins keep the unfused ablation
        assert!(CellSpec::lookup("lstm", 8).unwrap().has_unfused_ops());
        assert!(!CellSpec::lookup("gru", 8).unwrap().has_unfused_ops());
    }

    #[test]
    fn user_cells_register_and_instantiate() {
        // a user-defined cell: h' = tanh(xW + (h1 + h2)U + b), written
        // only as a program — no engine, model, or serve code
        fn mini(h: usize) -> Program {
            let mut p = Program::new("mini-reg-test", 2, h);
            let w = p.param("W", &[h, h]);
            let u = p.param("U", &[h, h]);
            let b = p.param("b", &[h]);
            let x = p.node(OpKind::Pull, vec![], h);
            let s1 = p.node(OpKind::Gather { slot: 0 }, vec![], h);
            let s2 = p.node(OpKind::Gather { slot: 1 }, vec![], h);
            let hs = p.node(OpKind::Add, vec![s1, s2], h);
            let gx = p.node(OpKind::MatMul { param: w }, vec![x], h);
            let gh = p.node(OpKind::MatMul { param: u }, vec![hs], h);
            let g = p.node(OpKind::Add, vec![gx, gh], h);
            let pre = p.node(OpKind::AddBias { param: b }, vec![g], h);
            let out = p.node(OpKind::Tanh, vec![pre], h);
            p.node(OpKind::Scatter, vec![out], h);
            p.node(OpKind::Push, vec![out], h);
            p
        }
        register_cell("mini-reg-test", mini).unwrap();
        assert!(registered_cells().iter().any(|n| n == "mini-reg-test"));
        // duplicate registration is an error
        assert!(register_cell("mini-reg-test", mini).is_err());
        assert!(register_cell("treelstm", mini).is_err(), "builtin shadowing");
        let spec = CellSpec::lookup("mini-reg-test", 4).unwrap();
        assert_eq!(spec.arity(), 2);
        assert_eq!(spec.gates_cols(), 4);
        let mut rng = Rng::new(1);
        let cell = spec.random_cell(&mut rng, 0.2).unwrap();
        use crate::exec::parallel::HostCell;
        assert_eq!(cell.n_params(), 3);
    }

    #[test]
    fn malformed_user_cell_is_rejected_at_registration() {
        fn broken(h: usize) -> Program {
            let mut p = Program::new("broken-reg-test", 1, h);
            let x = p.node(OpKind::Pull, vec![], h);
            p.node(OpKind::Push, vec![x], h);
            p // no gather, no scatter
        }
        let e = register_cell("broken-reg-test", broken).unwrap_err();
        assert!(format!("{e:#}").contains("registering cell"), "{e:#}");
        assert!(!is_registered("broken-reg-test"));
    }

    #[test]
    fn cell_names_with_separators_are_rejected() {
        fn ok(h: usize) -> Program {
            programs::treefc_program(h)
        }
        assert!(register_cell("bad name", ok).is_err());
        assert!(register_cell("bad_name", ok).is_err());
        assert!(register_cell("", ok).is_err());
    }
}
