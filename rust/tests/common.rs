//! Shared helpers for the PJRT integration tests (included via
//! `#[macro_use] mod common;` from each test crate — these are separate
//! binaries, so this file is the single home for the artifact gating).
#![allow(dead_code, unused_macros)]

use std::path::{Path, PathBuf};

/// The AOT artifact set produced by `python/compile/aot.py`.
pub fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Skip the enclosing test (early-return with a notice) when the AOT
/// artifact set is absent — clean checkouts and CI run without real PJRT
/// bindings, so everything needing kernel launches self-skips.
macro_rules! require_artifacts {
    () => {
        if !cavs::runtime::Runtime::have_artifacts(&crate::common::artifacts_dir()) {
            eprintln!(
                "skipping: no artifact set at {} (build with python/compile/aot.py)",
                crate::common::artifacts_dir().display()
            );
            return;
        }
    };
}
