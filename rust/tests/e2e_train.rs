//! End-to-end training tests on the quick artifact set (h=32):
//! loss must decrease, a finite-difference probe must validate the whole
//! batched-backprop machinery, and all three optimizers must make
//! progress. These run the complete stack: synthetic data -> scheduler ->
//! fused artifacts -> heads -> backward -> optimizer.

use cavs::exec::{Engine, EngineOpts};
use cavs::graph::{Dataset, InputGraph};
use cavs::models::{Cell, HeadKind, Model};
use cavs::runtime::Runtime;
use cavs::train::{train_epochs, ModelOptimizer};

#[macro_use]
mod common;
use common::artifacts_dir;

#[test]
fn treelstm_sentiment_loss_decreases() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut data = Dataset::sst_like(1, 24, 20, 5);
    // learnable labels: sign of mean token id
    for g in &mut data.graphs {
        let toks: Vec<i32> = g.tokens.iter().copied().filter(|&t| t >= 0).collect();
        let mean = toks.iter().map(|&t| t as f64).sum::<f64>() / toks.len() as f64;
        g.root_label = if mean > 4.0 { 1 } else { 0 };
    }
    let mut model = Model::new(Cell::TreeLstm, 32, 20, HeadKind::ClassifierAtRoot, 5, 3);
    let mut engine = Engine::new(&rt, EngineOpts::default());
    let logs = train_epochs(
        &mut engine, &mut model, &data, 8, ModelOptimizer::adam(0.01), 6, 5.0, |_| {},
    )
    .unwrap();
    let first = logs.first().unwrap().loss_per_label;
    let last = logs.last().unwrap().loss_per_label;
    assert!(last < first * 0.8, "loss {first} -> {last} did not decrease enough");
    assert!(last.is_finite());
}

#[test]
fn lstm_lm_loss_decreases() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let data = Dataset::ptb_like_fixed(2, 16, 50, 8);
    let mut model = Model::new(Cell::Lstm, 32, 50, HeadKind::LmPerVertex, 50, 4);
    let mut engine = Engine::new(&rt, EngineOpts::default());
    let logs = train_epochs(
        &mut engine, &mut model, &data, 8, ModelOptimizer::adam(0.01), 5, 5.0, |_| {},
    )
    .unwrap();
    assert!(
        logs.last().unwrap().loss_per_label < logs[0].loss_per_label,
        "LM loss must decrease"
    );
}

#[test]
fn gru_chain_loss_decreases() {
    require_artifacts!();
    // the extension cell trains end-to-end too
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let data = Dataset::ptb_like_fixed(5, 12, 50, 6);
    let mut model =
        Model::by_name("gru", 32, 50, HeadKind::LmPerVertex, 50, 6).unwrap();
    let mut engine = Engine::new(
        &rt,
        EngineOpts { lazy_batching: false, ..Default::default() },
    );
    let logs = train_epochs(
        &mut engine, &mut model, &data, 6, ModelOptimizer::adam(0.01), 5, 5.0, |_| {},
    )
    .unwrap();
    assert!(logs.last().unwrap().loss_per_label < logs[0].loss_per_label);
}

/// Finite differences through the ENTIRE stack: perturb one embedding
/// entry and one cell parameter, re-run the forward loss, and compare the
/// quotient against the gradient the batched backward produced.
#[test]
fn finite_difference_validates_full_backprop() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let data = Dataset::sst_like(9, 3, 20, 5);
    let graphs: Vec<&InputGraph> = data.graphs.iter().collect();

    let loss_of = |model: &mut Model| -> f32 {
        let mut engine = Engine::new(
            &rt,
            EngineOpts { training: false, ..Default::default() },
        );
        engine.run_minibatch(model, &graphs).unwrap().loss
    };

    let mut model = Model::new(Cell::TreeLstm, 32, 20, HeadKind::ClassifierAtRoot, 5, 13);
    let mut engine = Engine::new(&rt, EngineOpts::default());
    engine.run_minibatch(&mut model, &graphs).unwrap();

    // probe a few coordinates of Wiou (param 0) and the embedding
    let eps = 3e-3f32;
    for idx in [0usize, 17, 101] {
        let analytic = model.params.grad[0][idx];
        let orig = model.params.host[0][idx];
        model.params.host[0][idx] = orig + eps;
        model.params.invalidate();
        let lp = loss_of(&mut model);
        model.params.host[0][idx] = orig - eps;
        model.params.invalidate();
        let lm = loss_of(&mut model);
        model.params.host[0][idx] = orig;
        model.params.invalidate();
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (fd - analytic).abs() < 2e-2 * analytic.abs().max(0.5),
            "Wiou[{idx}]: fd {fd} vs analytic {analytic}"
        );
    }
    // one embedding row entry (token 1 appears in Zipf data w.h.p.)
    let e_idx = 1 * 32 + 5;
    let analytic = model.embedding.grad[e_idx];
    let orig = model.embedding.table[e_idx];
    model.embedding.table[e_idx] = orig + eps;
    let lp = loss_of(&mut model);
    model.embedding.table[e_idx] = orig - eps;
    let lm = loss_of(&mut model);
    model.embedding.table[e_idx] = orig;
    let fd = (lp - lm) / (2.0 * eps);
    assert!(
        (fd - analytic).abs() < 2e-2 * analytic.abs().max(0.5),
        "embedding: fd {fd} vs analytic {analytic}"
    );
}

#[test]
fn optimizers_all_make_progress() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    for opt in [
        ModelOptimizer::sgd(0.05),
        ModelOptimizer::Sgd { lr: 0.02, momentum: 0.9 },
        ModelOptimizer::Adagrad { lr: 0.05, eps: 1e-8 },
        ModelOptimizer::adam(0.01),
    ] {
        let data = Dataset::ptb_like_fixed(4, 8, 50, 6);
        let mut model = Model::new(Cell::Lstm, 32, 50, HeadKind::LmPerVertex, 50, 5);
        let mut engine = Engine::new(&rt, EngineOpts::default());
        let logs =
            train_epochs(&mut engine, &mut model, &data, 8, opt, 4, 5.0, |_| {})
                .unwrap();
        assert!(
            logs.last().unwrap().loss_per_label < logs[0].loss_per_label,
            "{opt:?} failed to reduce loss"
        );
    }
}

#[test]
fn inference_is_deterministic() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let data = Dataset::sst_like(6, 10, 20, 5);
    let graphs: Vec<&InputGraph> = data.graphs.iter().collect();
    let mut model = Model::new(Cell::TreeLstm, 32, 20, HeadKind::ClassifierAtRoot, 5, 8);
    let mut engine =
        Engine::new(&rt, EngineOpts { training: false, ..Default::default() });
    let a = engine.run_minibatch(&mut model, &graphs).unwrap();
    let b = engine.run_minibatch(&mut model, &graphs).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.ncorrect, b.ncorrect);
}

#[test]
fn batch_order_does_not_change_total_loss() {
    require_artifacts!();
    // summed minibatch loss is permutation-invariant across the batch
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let data = Dataset::sst_like(7, 6, 20, 5);
    let mut fwd: Vec<&InputGraph> = data.graphs.iter().collect();
    let mut model = Model::new(Cell::TreeLstm, 32, 20, HeadKind::ClassifierAtRoot, 5, 8);
    let mut engine =
        Engine::new(&rt, EngineOpts { training: false, ..Default::default() });
    let a = engine.run_minibatch(&mut model, &fwd).unwrap();
    fwd.reverse();
    let b = engine.run_minibatch(&mut model, &fwd).unwrap();
    assert!((a.loss - b.loss).abs() < 1e-3 * a.loss.abs().max(1.0));
}
