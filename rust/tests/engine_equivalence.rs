//! Cross-system numerical equivalence: every execution strategy — Cavs
//! (all engine-switch combinations), the DyNet-like agenda system, the
//! Fold-like depth system — computes the SAME function, so on identical
//! models and batches their losses and gradients must agree to float
//! tolerance. This pins down the paper's claim that Cavs "produces
//! exactly the same numerical results with other frameworks" (§5).

use cavs::baselines::dyndecl::DynDecl;
use cavs::baselines::fold::Fold;
use cavs::baselines::monolithic::{ScanLm, UnrollMode};
use cavs::exec::{Engine, EngineOpts, ExecOpts};
use cavs::graph::{Dataset, InputGraph};
use cavs::models::{Cell, HeadKind, Model};
use cavs::runtime::Runtime;
use cavs::util::rng::Rng;

#[macro_use]
mod common;
use common::artifacts_dir;

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() / b.abs().max(1.0) < tol
}

fn assert_grads_close(a: &Model, b: &Model, tol: f32, tag: &str) {
    for (i, name) in a.params.names.iter().enumerate() {
        let (ga, gb) = (&a.params.grad[i], &b.params.grad[i]);
        for (x, y) in ga.iter().zip(gb) {
            assert!(
                (x - y).abs() / y.abs().max(1.0) < tol,
                "{tag}: grad {name} mismatch {x} vs {y}"
            );
        }
    }
    for (x, y) in a.embedding.grad.iter().zip(&b.embedding.grad) {
        assert!(
            (x - y).abs() / y.abs().max(1.0) < tol,
            "{tag}: embedding grad mismatch {x} vs {y}"
        );
    }
}

const H: usize = 32;
const TOL: f32 = 2e-3;

fn tree_batch(seed: u64, k: usize) -> Vec<InputGraph> {
    let d = Dataset::sst_like(seed, k, 20, 5);
    d.graphs
}

fn fresh_model(cell: Cell, head: HeadKind, head_vocab: usize) -> Model {
    Model::new(cell, H, 20, head, head_vocab, 1234)
}

fn run_cavs(
    opts: EngineOpts,
    graphs: &[&InputGraph],
    cell: Cell,
    head: HeadKind,
    hv: usize,
) -> (f32, Model) {
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut model = fresh_model(cell, head, hv);
    let mut eng = Engine::new(&rt, opts);
    let r = eng.run_minibatch(&mut model, graphs).unwrap();
    (r.loss, model)
}

#[test]
fn all_cavs_switch_combinations_agree() {
    require_artifacts!();
    let graphs = tree_batch(5, 6);
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let (base_loss, base_model) = run_cavs(
        EngineOpts { lazy_batching: false, fusion: true, streaming: false, ..Default::default() },
        &refs,
        Cell::TreeLstm,
        HeadKind::ClassifierAtRoot,
        5,
    );
    for lazy in [false, true] {
        for fusion in [false, true] {
            for streaming in [false, true] {
                for threads in [1usize, 4] {
                    let (loss, model) = run_cavs(
                        EngineOpts {
                            lazy_batching: lazy,
                            fusion,
                            streaming,
                            exec: ExecOpts::with_threads(threads),
                            ..Default::default()
                        },
                        &refs,
                        Cell::TreeLstm,
                        HeadKind::ClassifierAtRoot,
                        5,
                    );
                    assert!(
                        rel_close(loss, base_loss, TOL),
                        "lazy={lazy} fusion={fusion} streaming={streaming} \
                         threads={threads}: {loss} vs {base_loss}"
                    );
                    assert_grads_close(
                        &model,
                        &base_model,
                        TOL,
                        &format!(
                            "lazy={lazy} fusion={fusion} stream={streaming} \
                             threads={threads}"
                        ),
                    );
                }
            }
        }
    }
}

/// The engine's parallel path must agree with its sequential path *exactly*
/// (bitwise): both run identical per-row copies/accumulations, only sharded.
#[test]
fn engine_threads_bitwise_identical() {
    require_artifacts!();
    let graphs = tree_batch(9, 6);
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let (base_loss, base_model) = run_cavs(
        EngineOpts::default(),
        &refs,
        Cell::TreeLstm,
        HeadKind::ClassifierAtRoot,
        5,
    );
    for threads in [2usize, 8] {
        let (loss, model) = run_cavs(
            EngineOpts {
                exec: ExecOpts::with_threads(threads),
                ..Default::default()
            },
            &refs,
            Cell::TreeLstm,
            HeadKind::ClassifierAtRoot,
            5,
        );
        assert_eq!(loss, base_loss, "threads={threads} changed the loss bits");
        for (i, (ga, gb)) in base_model
            .params
            .grad
            .iter()
            .zip(&model.params.grad)
            .enumerate()
        {
            assert_eq!(ga, gb, "threads={threads} grad tensor {i} diverged");
        }
        assert_eq!(
            base_model.embedding.grad, model.embedding.grad,
            "threads={threads} embedding grads diverged"
        );
    }
}

/// Forward-only inference (`Engine::infer_batch`, the serving entry
/// point) computes the same forward pass as the eval path, returns one
/// root score per graph, and keeps the dynamic-tensor chunks at
/// single-task size — the training run's Σ-task retention must cost
/// strictly more.
#[test]
fn infer_batch_matches_eval_and_skips_retention() {
    use cavs::graph::GraphBatch;

    require_artifacts!();
    let graphs = tree_batch(7, 6);
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let rt = Runtime::new(&artifacts_dir()).unwrap();

    // eval baseline (training=false through run_minibatch)
    let mut model = fresh_model(Cell::TreeLstm, HeadKind::ClassifierAtRoot, 5);
    let mut eval_eng = Engine::new(
        &rt,
        EngineOpts { training: false, ..Default::default() },
    );
    let eval = eval_eng.run_minibatch(&mut model, &refs).unwrap();
    let infer_cap = eval_eng.chunk_capacity_bytes();

    // serving path: pre-merged batch through infer_batch
    let mut model2 = fresh_model(Cell::TreeLstm, HeadKind::ClassifierAtRoot, 5);
    let mut eng = Engine::new(&rt, EngineOpts::default());
    let batch = GraphBatch::new(&refs, model2.cell.arity());
    let mut scores = Vec::new();
    let r = eng.infer_batch(&mut model2, &batch, &mut scores).unwrap();
    assert_eq!(r.loss, eval.loss, "infer_batch must match the eval forward");
    assert_eq!(scores.len(), graphs.len(), "one score per request");
    assert!(scores.iter().all(|s| s.is_finite()));
    assert!(
        eng.opts.training,
        "infer_batch must restore the engine's training flag"
    );

    // training retains Σ-task history; inference must not
    let mut model3 = fresh_model(Cell::TreeLstm, HeadKind::ClassifierAtRoot, 5);
    let mut train_eng = Engine::new(&rt, EngineOpts::default());
    train_eng.run_minibatch(&mut model3, &refs).unwrap();
    let train_cap = train_eng.chunk_capacity_bytes();
    assert!(
        infer_cap < train_cap,
        "inference chunks ({infer_cap} B) must stay below the training \
         retention ({train_cap} B)"
    );
}

#[test]
fn dyndecl_agrees_with_cavs() {
    require_artifacts!();
    let graphs = tree_batch(6, 5);
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let (cavs_loss, cavs_model) = run_cavs(
        EngineOpts::default(),
        &refs,
        Cell::TreeLstm,
        HeadKind::ClassifierAtRoot,
        5,
    );
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut model = fresh_model(Cell::TreeLstm, HeadKind::ClassifierAtRoot, 5);
    let mut sys = DynDecl::new(&rt);
    let r = sys.run_minibatch(&mut model, &refs, true).unwrap();
    assert!(rel_close(r.loss, cavs_loss, TOL), "{} vs {}", r.loss, cavs_loss);
    assert_grads_close(&model, &cavs_model, TOL, "dyndecl");
    assert!(sys.continuity_checks > 0, "continuity checks must run");
    assert!(sys.launches > 0);
}

#[test]
fn fold_agrees_with_cavs() {
    require_artifacts!();
    let graphs = tree_batch(7, 5);
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let (cavs_loss, cavs_model) = run_cavs(
        EngineOpts::default(),
        &refs,
        Cell::TreeLstm,
        HeadKind::ClassifierAtRoot,
        5,
    );
    for threads in [1, 4] {
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        let mut model = fresh_model(Cell::TreeLstm, HeadKind::ClassifierAtRoot, 5);
        let mut sys = Fold::new(&rt, threads);
        let r = sys.run_minibatch(&mut model, &refs, true).unwrap();
        assert!(
            rel_close(r.loss, cavs_loss, TOL),
            "fold-{threads}: {} vs {}",
            r.loss,
            cavs_loss
        );
        assert_grads_close(&model, &cavs_model, TOL, &format!("fold-{threads}"));
    }
}

#[test]
fn treefc_systems_agree() {
    require_artifacts!();
    let d = Dataset::treefc(8, 4, 20, 4);
    let refs: Vec<&InputGraph> = d.graphs.iter().collect();
    let (cavs_loss, cavs_model) =
        run_cavs(EngineOpts::default(), &refs, Cell::TreeFc, HeadKind::SumRootState, 0);
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut m1 = fresh_model(Cell::TreeFc, HeadKind::SumRootState, 0);
    let mut dd = DynDecl::new(&rt);
    let r1 = dd.run_minibatch(&mut m1, &refs, true).unwrap();
    assert!(rel_close(r1.loss, cavs_loss, TOL));
    assert_grads_close(&m1, &cavs_model, TOL, "dyndecl-treefc");

    let mut m2 = fresh_model(Cell::TreeFc, HeadKind::SumRootState, 0);
    let mut fd = Fold::new(&rt, 1);
    let r2 = fd.run_minibatch(&mut m2, &refs, true).unwrap();
    assert!(rel_close(r2.loss, cavs_loss, TOL));
    assert_grads_close(&m2, &cavs_model, TOL, "fold-treefc");
}

#[test]
fn scan_lm_agrees_with_cavs_on_chains() {
    require_artifacts!();
    // fixed-length chains of the quick scan artifact's T
    let t = 4usize;
    let mut rng = Rng::new(3);
    let graphs: Vec<InputGraph> = (0..2)
        .map(|_| {
            let toks: Vec<i32> = (0..=t).map(|_| rng.below(20) as i32).collect();
            InputGraph::chain(&toks[..t], &toks[1..])
        })
        .collect();
    let refs: Vec<&InputGraph> = graphs.iter().collect();

    // the scan artifact bakes Wemb's shape: embedding vocab must equal the
    // artifact's vocab (50 in the quick set)
    let mk = || Model::new(Cell::Lstm, H, 50, HeadKind::LmPerVertex, 50, 1234);
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let (cavs_loss, cavs_model) = {
        let mut model = mk();
        let mut eng = Engine::new(&rt, EngineOpts::default());
        let r = eng.run_minibatch(&mut model, &refs).unwrap();
        (r.loss, model)
    };
    let mut model = mk();
    let mut scan = ScanLm::new(&rt, UnrollMode::Static { t });
    let r = scan.run_minibatch(&mut model, &refs).unwrap();
    assert!(
        rel_close(r.loss, cavs_loss, TOL),
        "scan {} vs cavs {}",
        r.loss,
        cavs_loss
    );
    assert_grads_close(&model, &cavs_model, TOL, "scanlm");
    // the scan artifact computed exactly bs*t steps, all useful here
    assert_eq!(scan.steps_useful, (2 * t) as u64);
}

#[test]
fn gru_cell_runs_through_engine() {
    require_artifacts!();
    // GRU is a program-only cell: the engine reaches it purely through
    // the CellSpec registry (fused artifacts compiled under its name).
    let mut rng = Rng::new(9);
    let toks: Vec<i32> = (0..6).map(|_| rng.below(20) as i32).collect();
    let graph = InputGraph::chain(&toks[..5], &toks[1..]);
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut model =
        Model::by_name("gru", H, 20, HeadKind::LmPerVertex, 50, 1234).unwrap();
    let mut eng = Engine::new(
        &rt,
        EngineOpts { lazy_batching: false, ..Default::default() },
    );
    let r = eng.run_minibatch(&mut model, &[&graph]).unwrap();
    assert!(r.loss.is_finite() && r.loss > 0.0);
    assert!(model.params.grad_norm() > 0.0, "gru must backprop");
}
