//! The correctness keystone: replay the golden graphs (computed by jax in
//! python/compile/aot.py `make_goldens`) through the FULL Rust stack —
//! scheduler (Alg. 1), dynamic tensors (Alg. 2), gather/scatter buffers,
//! fused Pallas artifacts, heads, backward tape, lazy parameter grads —
//! and demand the same loss and gradients jax.grad produced for the whole
//! unrolled computation.

use cavs::exec::{Engine, EngineOpts};
use cavs::graph::InputGraph;
use cavs::models::{Cell, HeadKind, Model};
use cavs::runtime::Runtime;
use cavs::scheduler::Policy;
use cavs::util::json::Json;

#[macro_use]
mod common;
use common::artifacts_dir;

fn load_golden(name: &str) -> Json {
    let p = artifacts_dir().join("golden").join(name);
    let text = std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("missing golden {}: {e}", p.display()));
    Json::parse(&text).unwrap()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    let mut worst = 0.0f32;
    let mut worst_i = 0;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let denom = w.abs().max(1.0);
        let err = (g - w).abs() / denom;
        if err > worst {
            worst = err;
            worst_i = i;
        }
    }
    assert!(
        worst < tol,
        "{what}: worst rel err {worst} at {worst_i} (got {}, want {})",
        got[worst_i],
        want[worst_i]
    );
}

fn children_from(j: &Json) -> Vec<Vec<u32>> {
    j.as_arr()
        .unwrap()
        .iter()
        .map(|c| c.as_usize_vec().iter().map(|&v| v as u32).collect())
        .collect()
}

/// Build a model whose embedding table holds the golden per-vertex x
/// vectors (token id v => xs[v]), so `pull` feeds exactly the golden
/// inputs and the embedding gradient becomes grad_xs.
fn golden_model(g: &Json, cell: Cell, head_kind: HeadKind) -> Model {
    let h = g.get("h").unwrap().as_usize().unwrap();
    let xs = g.get("xs").unwrap();
    let n = xs.as_arr().unwrap().len();
    let head_vocab = g
        .get("vocab")
        .map(|v| v.as_usize().unwrap())
        .unwrap_or(1);
    let mut model = Model::new(cell, h, n, head_kind, head_vocab, 0);
    for (name, val) in g.get("params").unwrap().as_obj().unwrap() {
        model.params.set(name, val.as_f32_flat()).unwrap();
    }
    model.embedding.table = xs.as_f32_flat();
    model.embedding.grad = vec![0.0; n * h];
    if let Some(head) = g.get("head") {
        let hp = model.head.as_mut().unwrap();
        hp.set("Wout", head.get("Wout").unwrap().as_f32_flat()).unwrap();
        hp.set("bout", head.get("bout").unwrap().as_f32_flat()).unwrap();
    }
    model
}

fn check_param_grads(model: &Model, g: &Json, tol: f32) {
    let gp = g.get("grad_params").unwrap().as_obj().unwrap();
    for (i, name) in model.params.names.iter().enumerate() {
        let want = gp.get(name).unwrap().as_f32_flat();
        assert_close(&model.params.grad[i], &want, tol, name);
    }
    let want_gx = g.get("grad_xs").unwrap().as_f32_flat();
    assert_close(&model.embedding.grad, &want_gx, tol, "grad_xs");
    if let Some(gh) = g.get("grad_head") {
        let hp = model.head.as_ref().unwrap();
        assert_close(&hp.grad[0], &gh.get("Wout").unwrap().as_f32_flat(), tol, "gWout");
        assert_close(&hp.grad[1], &gh.get("bout").unwrap().as_f32_flat(), tol, "gbout");
    }
}

fn run_case(
    g: &Json,
    cell: Cell,
    head_kind: HeadKind,
    graph: &InputGraph,
    opts: EngineOpts,
    tol: f32,
    tag: &str,
) {
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut model = golden_model(g, cell, head_kind);
    let mut engine = Engine::new(&rt, opts);
    let res = engine.run_minibatch(&mut model, &[graph]).unwrap();
    let want_loss = g.get("loss").unwrap().as_f64().unwrap() as f32;
    assert!(
        (res.loss - want_loss).abs() / want_loss.abs().max(1.0) < tol,
        "{tag}: loss {} vs golden {want_loss}",
        res.loss
    );
    if opts.training {
        check_param_grads(&model, g, tol);
    }
}

fn treelstm_graph(g: &Json) -> InputGraph {
    let children = children_from(g.get("children").unwrap());
    let n = children.len();
    let label = g.get("label").unwrap().as_i64().unwrap() as i32;
    InputGraph::from_children(
        children,
        (0..n as i32).collect(),
        vec![-1; n],
        label,
    )
    .unwrap()
}

fn lstm_graph(g: &Json) -> InputGraph {
    let labels: Vec<i32> = g
        .get("labels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i32)
        .collect();
    let n = labels.len();
    InputGraph::chain(&(0..n as i32).collect::<Vec<_>>(), &labels)
}

fn treefc_graph(g: &Json) -> InputGraph {
    let children = children_from(g.get("children").unwrap());
    let n = children.len();
    InputGraph::from_children(children, (0..n as i32).collect(), vec![-1; n], -1)
        .unwrap()
}

const TOL: f32 = 2e-3;

// ---------------------------------------------------------------------
// Tree-LSTM sentiment tree
// ---------------------------------------------------------------------

#[test]
fn treelstm_golden_eager() {
    require_artifacts!();
    let g = load_golden("treelstm_tree.json");
    let graph = treelstm_graph(&g);
    let opts = EngineOpts { lazy_batching: false, ..Default::default() };
    run_case(&g, Cell::TreeLstm, HeadKind::ClassifierAtRoot, &graph, opts, TOL, "eager");
}

#[test]
fn treelstm_golden_lazy() {
    require_artifacts!();
    let g = load_golden("treelstm_tree.json");
    let graph = treelstm_graph(&g);
    let opts = EngineOpts { lazy_batching: true, ..Default::default() };
    run_case(&g, Cell::TreeLstm, HeadKind::ClassifierAtRoot, &graph, opts, TOL, "lazy");
}

#[test]
fn treelstm_golden_serial_policy() {
    require_artifacts!();
    let g = load_golden("treelstm_tree.json");
    let graph = treelstm_graph(&g);
    let opts = EngineOpts {
        policy: Policy::Serial,
        lazy_batching: false,
        ..Default::default()
    };
    run_case(&g, Cell::TreeLstm, HeadKind::ClassifierAtRoot, &graph, opts, TOL, "serial");
}

#[test]
fn treelstm_golden_unfused() {
    require_artifacts!();
    let g = load_golden("treelstm_tree.json");
    let graph = treelstm_graph(&g);
    let opts = EngineOpts {
        fusion: false,
        lazy_batching: false,
        ..Default::default()
    };
    run_case(&g, Cell::TreeLstm, HeadKind::ClassifierAtRoot, &graph, opts, TOL, "unfused");
}

#[test]
fn treelstm_golden_streaming() {
    require_artifacts!();
    let g = load_golden("treelstm_tree.json");
    let graph = treelstm_graph(&g);
    let opts = EngineOpts { streaming: true, ..Default::default() };
    run_case(&g, Cell::TreeLstm, HeadKind::ClassifierAtRoot, &graph, opts, TOL, "streaming");
}

#[test]
fn treelstm_golden_inference_loss() {
    require_artifacts!();
    let g = load_golden("treelstm_tree.json");
    let graph = treelstm_graph(&g);
    let opts = EngineOpts { training: false, ..Default::default() };
    run_case(&g, Cell::TreeLstm, HeadKind::ClassifierAtRoot, &graph, opts, TOL, "infer");
}

// ---------------------------------------------------------------------
// LSTM chain LM
// ---------------------------------------------------------------------

#[test]
fn lstm_chain_golden_eager() {
    require_artifacts!();
    let g = load_golden("lstm_chain.json");
    let graph = lstm_graph(&g);
    let opts = EngineOpts { lazy_batching: false, ..Default::default() };
    run_case(&g, Cell::Lstm, HeadKind::LmPerVertex, &graph, opts, TOL, "lm-eager");
}

#[test]
fn lstm_chain_golden_lazy() {
    require_artifacts!();
    let g = load_golden("lstm_chain.json");
    let graph = lstm_graph(&g);
    let opts = EngineOpts { lazy_batching: true, ..Default::default() };
    run_case(&g, Cell::Lstm, HeadKind::LmPerVertex, &graph, opts, TOL, "lm-lazy");
}

#[test]
fn lstm_chain_golden_unfused() {
    require_artifacts!();
    let g = load_golden("lstm_chain.json");
    let graph = lstm_graph(&g);
    let opts = EngineOpts {
        fusion: false,
        lazy_batching: false,
        ..Default::default()
    };
    run_case(&g, Cell::Lstm, HeadKind::LmPerVertex, &graph, opts, TOL, "lm-unfused");
}

// ---------------------------------------------------------------------
// Tree-FC (synthetic sum-of-root objective)
// ---------------------------------------------------------------------

#[test]
fn treefc_golden_eager() {
    require_artifacts!();
    let g = load_golden("treefc_tree.json");
    let graph = treefc_graph(&g);
    let opts = EngineOpts { lazy_batching: false, ..Default::default() };
    run_case(&g, Cell::TreeFc, HeadKind::SumRootState, &graph, opts, TOL, "fc-eager");
}

#[test]
fn treefc_golden_lazy() {
    require_artifacts!();
    let g = load_golden("treefc_tree.json");
    let graph = treefc_graph(&g);
    let opts = EngineOpts { lazy_batching: true, ..Default::default() };
    run_case(&g, Cell::TreeFc, HeadKind::SumRootState, &graph, opts, TOL, "fc-lazy");
}

// ---------------------------------------------------------------------
// Batched multi-graph consistency: summed loss of a 3-copy batch must be
// 3x the single-graph loss, and grads 3x (linearity of the sum).
// ---------------------------------------------------------------------

#[test]
fn batch_of_copies_scales_linearly() {
    require_artifacts!();
    let g = load_golden("treelstm_tree.json");
    let graph = treelstm_graph(&g);
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut model = golden_model(&g, Cell::TreeLstm, HeadKind::ClassifierAtRoot);
    let mut engine = Engine::new(&rt, EngineOpts::default());
    let res = engine
        .run_minibatch(&mut model, &[&graph, &graph, &graph])
        .unwrap();
    let want_loss = 3.0 * g.get("loss").unwrap().as_f64().unwrap() as f32;
    assert!(
        (res.loss - want_loss).abs() / want_loss.abs() < TOL,
        "batched loss {} vs {}",
        res.loss,
        want_loss
    );
    let gp = g.get("grad_params").unwrap().as_obj().unwrap();
    for (i, name) in model.params.names.iter().enumerate() {
        let want: Vec<f32> =
            gp.get(name).unwrap().as_f32_flat().iter().map(|x| 3.0 * x).collect();
        assert_close(&model.params.grad[i], &want, TOL, name);
    }
}
