//! Finite-difference validation of the Program interpreter's structural
//! backward (§3.4) for **all five shipped cells**, plus the open-API
//! acceptance tests: program-only cells (`gru`, `cstreelstm`) train
//! host-only end-to-end with decreasing loss and serve through the
//! serving stack — zero engine/models/serve edits beyond registration.
//!
//! Everything here is artifact-free (no PJRT runtime), so it runs on
//! every push in CI.

use cavs::exec::parallel::{run_host_frontier, HostCell};
use cavs::exec::MathMode;
use cavs::graph::{Dataset, GraphBatch, InputGraph};
use cavs::models::CellSpec;
use cavs::scheduler::{self, Policy};
use cavs::serve::{HostExec, Request, RequestQueue, ServeConfig, Server};
use cavs::train::host::HostTrainer;
use cavs::train::Sgd;
use cavs::util::rng::Rng;
use cavs::vertex::interp::ProgramCell;
use cavs::vertex::programs;
use cavs::vertex::{registry, OpKind, Program};

/// Weighted-output loss `L = Σ_j w_j · out_j` for one vertex, summed in
/// f64 so the finite-difference quotient is not noise-limited.
fn loss_of(
    cell: &ProgramCell,
    x: &[f32],
    s: &[f32],
    w: &[f32],
    tmp: &mut Vec<f32>,
) -> f64 {
    tmp.resize(cell.fwd_scratch_cols().max(1), 0.0);
    let mut out = vec![0.0f32; cell.state_cols()];
    cell.forward(x, s, &mut out, tmp);
    out.iter().zip(w).map(|(&o, &wj)| o as f64 * wj as f64).sum()
}

fn sample_indices(len: usize) -> Vec<usize> {
    let step = (len / 7).max(1);
    (0..len).step_by(step).collect()
}

fn assert_close(an: f64, fd: f64, what: &str) {
    // rel err <= 1e-3 on f32 forward values (central differences)
    let tol = 1e-3 * an.abs().max(fd.abs()).max(1.0);
    assert!(
        (fd - an).abs() <= tol,
        "{what}: fd {fd} vs analytic {an} (tol {tol})"
    );
}

/// Cell-level gradcheck: dL/dx, dL/ds (gather adjoints) and dL/dθ for
/// every parameter tensor, against central differences. `optimized`
/// runs the same check on the compiled `OptProgram` tape (views, wide
/// GEMMs, fused sweeps) instead of the reference per-node tape.
fn gradcheck_program_mode(program: Program, seed: u64, optimized: bool) {
    gradcheck_program_math(program, seed, optimized, MathMode::Exact);
}

/// [`gradcheck_program_mode`] with an explicit math mode: `fast` swaps in
/// the polynomial sigmoid/tanh kernels (DESIGN.md §11). The backward pass
/// differentiates through the *approximated* forward values, so analytic
/// and central-difference gradients still agree to the same 1e-3 bound.
fn gradcheck_program_math(
    program: Program,
    seed: u64,
    optimized: bool,
    math: MathMode,
) {
    let name = program.name.clone();
    let mut rng = Rng::new(seed);
    let mut cell = if optimized {
        ProgramCell::random_optimized(program, &mut rng, 0.2).unwrap()
    } else {
        ProgramCell::random(program, &mut rng, 0.2).unwrap()
    };
    cell.set_math(math);
    let xc = cell.x_cols();
    let sc_all = cell.state_cols() * cell.arity();
    let x: Vec<f32> = (0..xc).map(|_| rng.normal_f32(0.5)).collect();
    let s: Vec<f32> = (0..sc_all).map(|_| rng.normal_f32(0.5)).collect();
    let w: Vec<f32> =
        (0..cell.state_cols()).map(|_| rng.normal_f32(1.0)).collect();

    let mut gx = vec![0.0f32; xc];
    let mut gs = vec![0.0f32; sc_all];
    let mut tmp = vec![0.0f32; cell.bwd_scratch_cols()];
    cell.backward(&x, &s, &w, &mut gx, &mut gs, &mut tmp);
    let mut pg: Vec<Vec<f32>> =
        cell.params().iter().map(|p| vec![0.0; p.len()]).collect();
    let mut ptmp = vec![0.0f32; cell.pg_scratch_cols()];
    cell.acc_param_grads(&x, &s, &w, &mut pg, &mut ptmp);

    let eps = 1e-2f32;
    let mut ftmp = Vec::new();

    for j in sample_indices(xc) {
        let mut xp = x.clone();
        xp[j] += eps;
        let mut xm = x.clone();
        xm[j] -= eps;
        let fd = (loss_of(&cell, &xp, &s, &w, &mut ftmp)
            - loss_of(&cell, &xm, &s, &w, &mut ftmp))
            / (2.0 * eps as f64);
        assert_close(gx[j] as f64, fd, &format!("{name} gx[{j}]"));
    }
    for j in sample_indices(sc_all) {
        let mut sp = s.clone();
        sp[j] += eps;
        let mut sm = s.clone();
        sm[j] -= eps;
        let fd = (loss_of(&cell, &x, &sp, &w, &mut ftmp)
            - loss_of(&cell, &x, &sm, &w, &mut ftmp))
            / (2.0 * eps as f64);
        assert_close(gs[j] as f64, fd, &format!("{name} gs[{j}]"));
    }
    for pi in 0..pg.len() {
        for j in sample_indices(pg[pi].len()) {
            // every perturbation resyncs the compiled plan's merged GEMM
            // weights (no-op on the reference path / unmerged plans)
            let orig = cell.params()[pi][j];
            cell.params_mut()[pi][j] = orig + eps;
            cell.sync_opt();
            let lp = loss_of(&cell, &x, &s, &w, &mut ftmp);
            cell.params_mut()[pi][j] = orig - eps;
            cell.sync_opt();
            let lm = loss_of(&cell, &x, &s, &w, &mut ftmp);
            cell.params_mut()[pi][j] = orig;
            cell.sync_opt();
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert_close(
                pg[pi][j] as f64,
                fd,
                &format!("{name} param {pi}[{j}]"),
            );
        }
    }
}

fn gradcheck_program(program: Program, seed: u64) {
    gradcheck_program_mode(program, seed, false);
}

/// Host-training loss curve through the builder API (SGD at lr 0.02).
fn host_curve(
    spec: &CellSpec,
    data: &Dataset,
    epochs: usize,
    threads: usize,
    seed: u64,
) -> Vec<f64> {
    HostTrainer::builder(spec, data.vocab)
        .threads(threads)
        .seed(seed)
        .optimizer(Sgd::new(0.02))
        .build()
        .unwrap()
        .train_epochs(data, 4, epochs, |_| {})
        .into_iter()
        .map(|l| l.loss)
        .collect()
}

#[test]
fn gradcheck_all_five_cells() {
    let h = 5;
    gradcheck_program(programs::lstm_program(h), 11);
    gradcheck_program(programs::treelstm_program(h), 12);
    gradcheck_program(programs::treefc_program(h), 13);
    gradcheck_program(programs::gru_program(h), 14);
    gradcheck_program(programs::cstreelstm_program(h), 15);
}

/// FD gradcheck of the two DAG workloads (§4): the sum-aggregating GNN
/// message-passing cell (fan-in 4) and the attention seq2seq cell
/// (softmax over a 3-slot memory) pass the same 1e-3 relative bound as
/// the tree/chain cells — in the reference interpreter, on the compiled
/// tapes, and under fast math.
#[test]
fn gradcheck_dag_cells() {
    let h = 5;
    gradcheck_program(programs::gnn_program(h), 16);
    gradcheck_program(programs::attnseq2seq_program(h), 17);
    gradcheck_program_mode(programs::gnn_program(h), 26, true);
    gradcheck_program_mode(programs::attnseq2seq_program(h), 27, true);
    gradcheck_program_math(programs::gnn_program(h), 46, true, MathMode::Fast);
    gradcheck_program_math(
        programs::attnseq2seq_program(h),
        47,
        true,
        MathMode::Fast,
    );
}

/// FD gradcheck directly on the **compiled** `OptProgram` tapes: the
/// structural backward over the optimized value layout (folded views,
/// concatenated gate GEMMs, fused elementwise groups) must carry the
/// same analytic gradients as the reference interpreter does.
#[test]
fn gradcheck_all_five_cells_on_optimized_tapes() {
    let h = 5;
    gradcheck_program_mode(programs::lstm_program(h), 21, true);
    gradcheck_program_mode(programs::treelstm_program(h), 22, true);
    gradcheck_program_mode(programs::treefc_program(h), 23, true);
    gradcheck_program_mode(programs::gru_program(h), 24, true);
    gradcheck_program_mode(programs::cstreelstm_program(h), 25, true);
}

/// Acceptance for `--set math=fast`: the full FD gradcheck — gx, gs and
/// every parameter tensor — passes the same 1e-3 relative bound for all
/// five cells with the vectorized polynomial activations enabled. Fast
/// math only exists on the compiled path (`optimized = true`); on a
/// reference cell `set_math` is a no-op.
#[test]
fn gradcheck_all_five_cells_fast_math() {
    let h = 5;
    gradcheck_program_math(programs::lstm_program(h), 41, true, MathMode::Fast);
    gradcheck_program_math(programs::treelstm_program(h), 42, true, MathMode::Fast);
    gradcheck_program_math(programs::treefc_program(h), 43, true, MathMode::Fast);
    gradcheck_program_math(programs::gru_program(h), 44, true, MathMode::Fast);
    gradcheck_program_math(
        programs::cstreelstm_program(h),
        45,
        true,
        MathMode::Fast,
    );
}

/// End-to-end frontier gradcheck: the whole choreography — pull, gather,
/// scatter-add, level backward, sequential parameter accumulation —
/// against finite differences on a real multi-graph batch (gru).
/// `spec.instantiate` binds the **compiled** plan, so this exercises the
/// default (optimized, level-batched) execution path.
#[test]
fn host_frontier_gradcheck_end_to_end() {
    let h = 4;
    let vocab = 12usize;
    let spec = CellSpec::lookup("gru", h).unwrap();
    let mut rng = Rng::new(21);
    let graphs: Vec<InputGraph> = (0..4)
        .map(|_| {
            let len = 2 + rng.below(5);
            let toks: Vec<i32> =
                (0..len).map(|_| rng.below(vocab) as i32).collect();
            let labs = vec![-1; len];
            InputGraph::chain(&toks, &labs)
        })
        .collect();
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let batch = GraphBatch::new(&refs, 1);
    let tasks = schedule_host(&batch);
    let params: Vec<Vec<f32>> = spec
        .param_shapes()
        .iter()
        .map(|p| (0..p.elements()).map(|_| rng.normal_f32(0.2)).collect())
        .collect();
    let xtable: Vec<f32> =
        (0..vocab * h).map(|_| rng.normal_f32(0.5)).collect();

    let loss = |params: &[Vec<f32>], xtable: &[f32]| -> f64 {
        let cell = spec.instantiate(params.to_vec()).unwrap();
        let r = run_host_frontier(&batch, &tasks, &cell, xtable, 1, false);
        batch
            .roots
            .iter()
            .map(|&v| {
                r.states
                    .row(v as usize)
                    .iter()
                    .map(|&x| x as f64)
                    .sum::<f64>()
            })
            .sum()
    };

    let cell = spec.instantiate(params.clone()).unwrap();
    let r = run_host_frontier(&batch, &tasks, &cell, &xtable, 1, true);
    let pg = r.param_grads.unwrap();
    let xg = r.x_grads.unwrap();

    let eps = 1e-2f32;
    let close = |an: f64, fd: f64, what: &str| {
        let tol = 2e-3 * an.abs().max(fd.abs()).max(1.0);
        assert!((fd - an).abs() <= tol, "{what}: fd {fd} vs analytic {an}");
    };
    for (pi, idx) in [(0usize, 0usize), (0, 7), (1, 5), (2, 3)] {
        let mut pp = params.clone();
        pp[pi][idx] += eps;
        let mut pm = params.clone();
        pm[pi][idx] -= eps;
        let fd = (loss(&pp, &xtable) - loss(&pm, &xtable)) / (2.0 * eps as f64);
        close(pg[pi][idx] as f64, fd, &format!("param {pi}[{idx}]"));
    }
    // an embedding row that actually occurs (token of the first vertex)
    let tok = batch.tokens[0].max(0) as usize;
    let e_idx = tok * h + 1;
    let mut xp = xtable.clone();
    xp[e_idx] += eps;
    let mut xm = xtable.clone();
    xm[e_idx] -= eps;
    let fd = (loss(&params, &xp) - loss(&params, &xm)) / (2.0 * eps as f64);
    close(xg[e_idx] as f64, fd, "xtable entry");
}

fn schedule_host(batch: &GraphBatch) -> Vec<cavs::scheduler::Task> {
    scheduler::schedule(batch, Policy::Batched, &scheduler::host_buckets())
}

/// Acceptance: the two program-only cells train host-only end-to-end
/// with decreasing loss — no artifacts, no engine edits.
#[test]
fn program_only_cells_train_end_to_end() {
    let gru = CellSpec::lookup("gru", 6).unwrap();
    let data = Dataset::ptb_like_var(5, 12, 20, 8);
    let losses = host_curve(&gru, &data, 5, 2, 7);
    assert!(
        losses.last().unwrap() < &losses[0],
        "gru loss {} -> {}",
        losses[0],
        losses.last().unwrap()
    );

    let cst = CellSpec::lookup("cstreelstm", 6).unwrap();
    let data = Dataset::sst_like(6, 12, 20, 5);
    let losses = host_curve(&cst, &data, 5, 2, 7);
    assert!(
        losses.last().unwrap() < &losses[0],
        "cstreelstm loss {} -> {}",
        losses[0],
        losses.last().unwrap()
    );
}

/// Acceptance: a cell a *user* registers at runtime — written only as a
/// Program — immediately trains AND serves through the generic stack.
#[test]
fn user_registered_cell_trains_and_serves() {
    fn leaky_gru(h: usize) -> Program {
        // a GRU variant with an extra tanh squash on the candidate mix
        let mut p = Program::new("leaky-gru-e2e", 1, h);
        let w = p.param("W", &[h, 2 * h]);
        let u = p.param("U", &[h, 2 * h]);
        let b = p.param("b", &[2 * h]);
        let x = p.node(OpKind::Pull, vec![], h);
        let hp = p.node(OpKind::Gather { slot: 0 }, vec![], h);
        let gx = p.node(OpKind::MatMul { param: w }, vec![x], 2 * h);
        let gh = p.node(OpKind::MatMul { param: u }, vec![hp], 2 * h);
        let gsum = p.node(OpKind::Add, vec![gx, gh], 2 * h);
        let pre = p.node(OpKind::AddBias { param: b }, vec![gsum], 2 * h);
        let pz = p.node(OpKind::SliceCols { start: 0, len: h }, vec![pre], h);
        let pn = p.node(OpKind::SliceCols { start: h, len: h }, vec![pre], h);
        let z = p.node(OpKind::Sigmoid, vec![pz], h);
        let n = p.node(OpKind::Tanh, vec![pn], h);
        let zc = p.node(OpKind::OneMinus, vec![z], h);
        let zn = p.node(OpKind::Mul, vec![zc, n], h);
        let zh = p.node(OpKind::Mul, vec![z, hp], h);
        let hnew = p.node(OpKind::Add, vec![zn, zh], h);
        p.node(OpKind::Scatter, vec![hnew], h);
        p.node(OpKind::Push, vec![hnew], h);
        p
    }
    registry::register_cell("leaky-gru-e2e", leaky_gru).unwrap();
    gradcheck_program(leaky_gru(5), 31);
    // the user cell's compiled tape gradchecks too
    gradcheck_program_mode(leaky_gru(5), 32, true);

    let spec = CellSpec::lookup("leaky-gru-e2e", 6).unwrap();
    let data = Dataset::ptb_like_var(9, 10, 20, 8);
    let losses = host_curve(&spec, &data, 4, 1, 3);
    assert!(losses.last().unwrap() < &losses[0]);

    // ...and serve it
    let exec = HostExec::from_spec(&spec, 20, 2, 7).unwrap();
    let mut server =
        Server::with_policy(exec, ServeConfig::default().make_policy());
    let q = RequestQueue::bounded(16);
    let reqs = cavs::serve::loadgen::mixed_workload(3, 7, 20, 1);
    for (id, g) in reqs.into_iter().enumerate() {
        q.try_enqueue(Request::new(id as u64, g).unwrap()).unwrap();
    }
    q.close();
    let mut n = 0usize;
    server
        .run(&q, |resp| {
            assert!(resp.prediction.score.is_finite());
            n += 1;
        })
        .unwrap();
    assert_eq!(n, 7);
}
