//! Runtime kernel-dispatch coverage for the SIMD microkernels
//! (DESIGN.md §11). Exact math mode is a **bitwise** contract: every
//! variant the host CPU supports — scalar always, AVX2+FMA or NEON when
//! detected — must reproduce the reference interpreter's forward,
//! backward and parameter-gradient results bit for bit, at the cell
//! level and through the whole level-batched frontier. The exact SIMD
//! kernels keep separate mul+add and per-lane scalar-order reductions
//! precisely so this holds. Fast math is accepted by tolerance instead
//! (the full finite-difference gradcheck lives in `gradcheck.rs`).

use cavs::exec::parallel::{HostCell, HostFrontier};
use cavs::exec::pool::Sharder;
use cavs::exec::{MathMode, Variant};
use cavs::graph::{synth, GraphBatch, InputGraph};
use cavs::models::CellSpec;
use cavs::scheduler::{schedule, Policy, Task};
use cavs::util::rng::Rng;
use cavs::vertex::interp::ProgramCell;
use cavs::vertex::programs;

/// Chains or shared trees sized so frontier levels span rows from 1 up
/// past `GEMM_ROW_BLOCK`: the packed kernels hit both the blocked body
/// and the remainder tail.
fn build_batch(arity: usize, vocab: usize) -> (GraphBatch, Vec<Task>) {
    let mut rng = Rng::new(97);
    let graphs: Vec<InputGraph> = (0..6)
        .map(|i| {
            if arity >= 2 {
                synth::random_binary_tree(&mut rng, vocab, 3 + i, 5)
            } else {
                let len = 3 + i;
                let toks: Vec<i32> =
                    (0..len).map(|_| rng.below(vocab) as i32).collect();
                let labs = vec![-1; len];
                InputGraph::chain(&toks, &labs)
            }
        })
        .collect();
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let batch = GraphBatch::new(&refs, arity);
    let tasks = schedule(&batch, Policy::Batched, &[1, 2, 4, 8, 16]);
    (batch, tasks)
}

/// Full fwd+bwd+param-grad frontier pass; returns everything observable.
fn run_frontier(
    cell: &ProgramCell,
    batch: &GraphBatch,
    tasks: &[Task],
    xtable: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<Vec<f32>>) {
    let mut hf = HostFrontier::new();
    hf.run(batch, tasks, cell, xtable, Sharder::Sequential, true);
    (
        hf.states().as_slice().to_vec(),
        hf.grads().unwrap().as_slice().to_vec(),
        hf.param_grads().unwrap().to_vec(),
    )
}

/// Cell-level fwd+bwd+param-grads on one vertex (the per-row path).
fn eval_cell(cell: &ProgramCell, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(seed);
    let xc = cell.x_cols();
    let sc_all = cell.state_cols() * cell.arity();
    let x: Vec<f32> = (0..xc).map(|_| rng.normal_f32(0.5)).collect();
    let s: Vec<f32> = (0..sc_all).map(|_| rng.normal_f32(0.5)).collect();
    let w: Vec<f32> =
        (0..cell.state_cols()).map(|_| rng.normal_f32(1.0)).collect();
    let mut out = vec![0.0f32; cell.state_cols()];
    let mut ftmp = vec![0.0f32; cell.fwd_scratch_cols().max(1)];
    cell.forward(&x, &s, &mut out, &mut ftmp);
    let mut gx = vec![0.0f32; xc];
    let mut gs = vec![0.0f32; sc_all];
    let mut btmp = vec![0.0f32; cell.bwd_scratch_cols()];
    cell.backward(&x, &s, &w, &mut gx, &mut gs, &mut btmp);
    let mut pg: Vec<Vec<f32>> =
        cell.params().iter().map(|p| vec![0.0; p.len()]).collect();
    let mut ptmp = vec![0.0f32; cell.pg_scratch_cols()];
    cell.acc_param_grads(&x, &s, &w, &mut pg, &mut ptmp);
    (out, gx, gs, pg)
}

/// Every CPU-supported variant, forced through `set_kernel_variant`,
/// reproduces the reference interpreter bit for bit on a whole
/// level-batched frontier pass (exact mode): states, input gradients and
/// accumulated parameter gradients. This is the invariant that lets
/// `--set math=exact` (the default) stay bitwise reproducible across
/// machines with different SIMD support.
#[test]
fn forced_variants_bitwise_match_reference_in_exact_mode() {
    for name in ["gru", "treelstm"] {
        let h = 8;
        let vocab = 20usize;
        let spec = CellSpec::lookup(name, h).unwrap();
        let (batch, tasks) = build_batch(spec.arity(), vocab);

        let mut rng = Rng::new(7);
        let reference = spec.random_cell_unoptimized(&mut rng, 0.2).unwrap();
        let xtable: Vec<f32> =
            (0..vocab * spec.x_cols()).map(|_| rng.normal_f32(0.5)).collect();
        let want = run_frontier(&reference, &batch, &tasks, &xtable);

        for v in Variant::all() {
            if !v.available() {
                continue;
            }
            // same seed => identical parameters and embedding table
            let mut rng = Rng::new(7);
            let mut cell = spec.random_cell(&mut rng, 0.2).unwrap();
            assert!(cell.set_kernel_variant(v), "{name}: {v:?} probed available");
            assert_eq!(cell.kernel_variant(), Some(v));
            assert_eq!(cell.math(), MathMode::Exact);
            let got = run_frontier(&cell, &batch, &tasks, &xtable);
            assert_eq!(got.0, want.0, "{name}/{}: states diverged", v.name());
            assert_eq!(got.1, want.1, "{name}/{}: grads diverged", v.name());
            assert_eq!(got.2, want.2, "{name}/{}: param grads diverged", v.name());
        }
    }
}

/// The same bitwise contract on the per-row (cell-level) entry points,
/// for all five shipped cells — these feed the serving path's small
/// batches, where the SIMD kernels run with `rows = 1`.
#[test]
fn forced_variants_bitwise_match_reference_per_row() {
    let h = 6;
    let cells = [
        programs::lstm_program(h),
        programs::treelstm_program(h),
        programs::treefc_program(h),
        programs::gru_program(h),
        programs::cstreelstm_program(h),
    ];
    for program in cells {
        let name = program.name.clone();
        let mut rng = Rng::new(51);
        let reference =
            ProgramCell::random(program.clone(), &mut rng, 0.2).unwrap();
        let want = eval_cell(&reference, 52);

        for v in Variant::all() {
            if !v.available() {
                continue;
            }
            let mut rng = Rng::new(51);
            let mut cell =
                ProgramCell::random_optimized(program.clone(), &mut rng, 0.2)
                    .unwrap();
            assert!(cell.set_kernel_variant(v));
            let got = eval_cell(&cell, 52);
            assert_eq!(got, want, "{name}/{}: per-row results diverged", v.name());
        }
    }
}

/// Fast math trades bitwise identity for throughput: forced through the
/// same dispatch table, its forward/backward results stay within a 1e-3
/// relative bound of exact mode (the polynomial kernels themselves are
/// accurate to ~1e-5; the bound leaves headroom for composition).
#[test]
fn fast_math_stays_within_tolerance_of_exact() {
    let close = |a: &[f32], b: &[f32], what: &str| {
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let tol = 1e-3 * x.abs().max(y.abs()).max(1.0);
            assert!(
                (x - y).abs() <= tol,
                "{what}[{i}]: fast {y} vs exact {x} (tol {tol})"
            );
        }
    };
    let h = 6;
    for (seed, program) in
        [(61, programs::gru_program(h)), (62, programs::treelstm_program(h))]
    {
        let name = program.name.clone();
        let mut rng = Rng::new(seed);
        let exact =
            ProgramCell::random_optimized(program.clone(), &mut rng, 0.2)
                .unwrap();
        let want = eval_cell(&exact, seed + 100);

        let mut rng = Rng::new(seed);
        let mut cell =
            ProgramCell::random_optimized(program, &mut rng, 0.2).unwrap();
        cell.set_math(MathMode::Fast);
        assert_eq!(cell.math(), MathMode::Fast);
        let got = eval_cell(&cell, seed + 100);
        close(&want.0, &got.0, &format!("{name} out"));
        close(&want.1, &got.1, &format!("{name} gx"));
        close(&want.2, &got.2, &format!("{name} gs"));
        for (pi, (wp, gp)) in want.3.iter().zip(&got.3).enumerate() {
            close(wp, gp, &format!("{name} param {pi}"));
        }
    }
}

/// Dispatch-control edge cases: unavailable variants are refused with the
/// table untouched; reference cells have no kernel table at all, so both
/// `set_kernel_variant` and `set_math` are inert on them.
#[test]
fn dispatch_controls_reject_unavailable_and_reference_cells() {
    let h = 5;
    let mut rng = Rng::new(71);
    let mut opt =
        ProgramCell::random_optimized(programs::gru_program(h), &mut rng, 0.2)
            .unwrap();
    assert!(opt.is_optimized());
    let detected = Variant::detect();
    assert!(detected.available());
    assert_eq!(opt.kernel_variant(), Some(detected), "cells bind the detected variant");
    for v in Variant::all() {
        if v.available() {
            assert!(opt.set_kernel_variant(v));
            assert_eq!(opt.kernel_variant(), Some(v));
        } else {
            let before = opt.kernel_variant();
            assert!(!opt.set_kernel_variant(v), "{v:?} must be refused");
            assert_eq!(opt.kernel_variant(), before, "refusal left table untouched");
        }
    }
    // scalar is universal: forcing it always succeeds
    assert!(opt.set_kernel_variant(Variant::Scalar));

    let mut rng = Rng::new(71);
    let mut reference =
        ProgramCell::random(programs::gru_program(h), &mut rng, 0.2).unwrap();
    assert!(!reference.is_optimized());
    assert_eq!(reference.kernel_variant(), None);
    assert!(!reference.set_kernel_variant(Variant::Scalar), "no table to force");
    reference.set_math(MathMode::Fast);
    assert_eq!(reference.math(), MathMode::Exact, "set_math is a no-op off-plan");
}
