//! Property tests over the coordinator invariants (hand-rolled driver in
//! util::propcheck — proptest is unavailable offline). Replay failures
//! with `CAVS_PROP_SEED=<seed>`; scale effort with `CAVS_PROP_CASES`.

use cavs::exec::parallel::{run_host_frontier, HostFrontier, HostLstm, HostTreeFc};
use cavs::exec::pool::{Sharder, WorkerPool};
use cavs::graph::{synth, GraphBatch, InputGraph};
use cavs::memory::{MemTraffic, StateBuffer};
use cavs::scheduler::{frontier_levels, schedule, stats, Policy};
use cavs::tensor::DynamicTensor;
use cavs::util::propcheck::check;
use cavs::util::rng::Rng;
use cavs::vertex::OpKind;

const BUCKETS: &[usize] = &[1, 2, 4, 8, 16, 32];

fn random_graphs(rng: &mut Rng) -> Vec<InputGraph> {
    let k = 1 + rng.below(8);
    (0..k)
        .map(|_| match rng.below(4) {
            0 => {
                let len = 1 + rng.below(12);
                let toks: Vec<i32> = (0..len).map(|_| rng.below(20) as i32).collect();
                let labs: Vec<i32> = (0..len).map(|_| rng.below(20) as i32).collect();
                InputGraph::chain(&toks, &labs)
            }
            1 => {
                let leaves = 1 + rng.below(20);
                synth::random_binary_tree(rng, 20, leaves, 5)
            }
            2 => {
                let leaves = 1 << (1 + rng.below(4));
                synth::complete_binary_tree(rng, 20, leaves)
            }
            _ => {
                let (layers, width, arity) =
                    (1 + rng.below(4), 1 + rng.below(4), 1 + rng.below(2));
                synth::random_dag(rng, 20, layers, width, arity)
            }
        })
        .collect()
}

/// Every vertex is scheduled exactly once, dependencies are respected,
/// buckets cover task sizes, and padding accounting is exact.
#[test]
fn prop_schedule_is_a_valid_execution_order() {
    check("schedule-valid", 150, |rng| {
        let graphs = random_graphs(rng);
        let arity = graphs
            .iter()
            .flat_map(|g| g.children.iter())
            .map(Vec::len)
            .max()
            .unwrap_or(1)
            .max(1);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, arity);
        let policy = if rng.below(2) == 0 { Policy::Batched } else { Policy::Serial };
        let tasks = schedule(&batch, policy, BUCKETS);

        let mut done = vec![false; batch.n_vertices];
        for t in &tasks {
            assert!(t.m() >= 1 && t.m() <= t.bucket);
            assert!(BUCKETS.contains(&t.bucket));
            for &v in &t.verts {
                for slot in 0..arity {
                    if let Some(c) = batch.child(v, slot) {
                        assert!(done[c as usize], "dependency violated");
                    }
                }
            }
            for &v in &t.verts {
                assert!(!done[v as usize], "vertex scheduled twice");
                done[v as usize] = true;
            }
        }
        assert!(done.iter().all(|&d| d), "vertex never scheduled");
        let s = stats(&tasks);
        assert_eq!(s.n_vertices, batch.n_vertices);
        assert_eq!(
            s.padded_rows,
            tasks.iter().map(|t| t.bucket - t.m()).sum::<usize>()
        );
    });
}

/// The runtime frontier BFS (Alg. 1) groups vertices exactly by their
/// precomputed longest-path depth.
#[test]
fn prop_frontier_equals_depth_grouping() {
    check("frontier-depth", 150, |rng| {
        let graphs = random_graphs(rng);
        let arity = graphs
            .iter()
            .flat_map(|g| g.children.iter())
            .map(Vec::len)
            .max()
            .unwrap_or(1)
            .max(1);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, arity);
        let mut a = frontier_levels(&batch);
        let mut b = batch.levels();
        for l in a.iter_mut().chain(b.iter_mut()) {
            l.sort_unstable();
        }
        assert_eq!(a, b);
    });
}

/// Multi-parent DAG workloads for the generalized scheduler: GNN
/// message-passing graphs and attention seq2seq graphs, the two shapes
/// the new cells batch.
fn random_dag_workloads(rng: &mut Rng) -> Vec<InputGraph> {
    let k = 1 + rng.below(6);
    (0..k)
        .map(|_| {
            if rng.below(2) == 0 {
                let layers = 1 + rng.below(4);
                let width = 2 + rng.below(3);
                synth::gnn_dag(rng, 20, layers, width, 4, 5)
            } else {
                synth::seq2seq_copy(rng, 20, 3, 10, 3)
            }
        })
        .collect()
}

/// DAG generalization of the schedule validity property: with genuine
/// multi-parent fan-in in every batch, the scheduler still evaluates
/// every parent strictly after *all* of its children — per edge, not per
/// tree path — and the frontier levels plus the static DAG proof agree.
#[test]
fn prop_dag_schedule_respects_all_parents_before_child() {
    use cavs::analysis::plan::check_dag_frontier;

    check("dag-schedule-valid", 100, |rng| {
        let graphs = random_dag_workloads(rng);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, 4);

        // the generated batches genuinely exercise fan-in: some vertex
        // has at least two distinct parents
        let mut n_parents = vec![0usize; batch.n_vertices];
        for v in 0..batch.n_vertices as u32 {
            for slot in 0..batch.arity {
                if let Some(c) = batch.child(v, slot) {
                    n_parents[c as usize] += 1;
                }
            }
        }
        assert!(
            n_parents.iter().any(|&p| p >= 2),
            "workload generator produced no multi-parent vertex"
        );

        check_dag_frontier(&batch).unwrap();
        let policy =
            if rng.below(2) == 0 { Policy::Batched } else { Policy::Serial };
        let tasks = schedule(&batch, policy, BUCKETS);
        let mut done = vec![false; batch.n_vertices];
        for t in &tasks {
            for &v in &t.verts {
                for slot in 0..batch.arity {
                    if let Some(c) = batch.child(v, slot) {
                        assert!(
                            done[c as usize],
                            "parent {v} ran before child {c}"
                        );
                    }
                }
            }
            for &v in &t.verts {
                assert!(!done[v as usize], "vertex {v} scheduled twice");
                done[v as usize] = true;
            }
        }
        assert!(done.iter().all(|&d| d));
        // frontier levels group exactly by longest-path depth on DAGs too
        let mut a = frontier_levels(&batch);
        let mut b = batch.levels();
        for l in a.iter_mut().chain(b.iter_mut()) {
            l.sort_unstable();
        }
        assert_eq!(a, b);
    });
}

/// Corrupting a DAG batch is always caught by the static plan passes:
/// dropping every child edge of the deepest vertex breaks the stored
/// depth against the longest-path recomputation, and smuggling a cycle
/// through an input vertex starves the frontier propagation. The level
/// checker independently rejects the cycle as a dependency violation.
#[test]
fn prop_corrupted_dag_batches_are_rejected() {
    use cavs::analysis::plan::{check_batch, check_dag_frontier, check_levels};
    use cavs::analysis::SoundnessError;
    use cavs::graph::batch::NO_VERTEX;

    check("dag-corruption-rejected", 60, |rng| {
        let graphs = random_dag_workloads(rng);
        let refs: Vec<&InputGraph> = graphs.iter().collect();

        // dropped edges: sever the deepest vertex's children entirely —
        // its stored depth can no longer be justified by any path
        let mut batch = GraphBatch::new(&refs, 4);
        let deepest = (0..batch.n_vertices as u32)
            .max_by_key(|&v| batch.depth[v as usize])
            .unwrap();
        assert!(batch.depth[deepest as usize] >= 1);
        for slot in 0..batch.arity {
            batch.corrupt_child_slot(deepest, slot, NO_VERTEX);
        }
        assert!(matches!(
            check_dag_frontier(&batch),
            Err(SoundnessError::DepthMismatch { .. })
        ));

        // smuggled cycle: an input vertex of some graph points back at
        // that graph's root, which transitively depends on it
        let mut batch = GraphBatch::new(&refs, 4);
        let levels = frontier_levels(&batch);
        let root = batch.roots[rng.below(batch.roots.len())];
        let v0 = (0..batch.n_vertices as u32)
            .find(|&v| {
                batch.depth[v as usize] == 0
                    && batch.owner[v as usize] == batch.owner[root as usize]
            })
            .unwrap();
        batch.corrupt_child_slot(v0, 0, root);
        assert!(matches!(
            check_dag_frontier(&batch),
            Err(SoundnessError::FrontierCycle { .. })
        ));
        // the per-edge structural pass and the level replay both refuse
        // the corrupted batch as well
        assert!(check_batch(&batch).is_err());
        assert!(matches!(
            check_levels(&batch, &levels),
            Err(SoundnessError::DependencyViolation { .. }
                | SoundnessError::LevelReadWriteOverlap { .. })
        ));
    });
}

/// Dynamic-tensor forward advance / backward rewind is exact LIFO: after
/// any sequence of tasks, rewinding in reverse recovers every view
/// verbatim and lands at offset zero (Alg. 2's memory choreography).
#[test]
fn prop_dynamic_tensor_lifo_roundtrip() {
    check("dyntensor-lifo", 200, |rng| {
        let cols = 1 + rng.below(16);
        let mut dt = DynamicTensor::new(&[cols]);
        let n_tasks = 1 + rng.below(20);
        let buckets: Vec<usize> =
            (0..n_tasks).map(|_| 1 << rng.below(6)).collect();
        let mut stamps = Vec::new();
        for (i, &b) in buckets.iter().enumerate() {
            dt.set_bs(b);
            for r in 0..b {
                let val = (i * 1000 + r) as f32;
                dt.row_mut(r).fill(val);
            }
            stamps.push(b);
            dt.advance();
        }
        for (i, &b) in buckets.iter().enumerate().rev() {
            dt.rewind(b).unwrap();
            for r in 0..b {
                assert_eq!(dt.row(r)[0], (i * 1000 + r) as f32);
            }
        }
        assert_eq!(dt.offset_rows(), 0);
        assert!(dt.rewind(1).is_err(), "rewind past zero must fail");
    });
}

/// gather ∘ scatter is the identity on the scattered rows, zero elsewhere;
/// scatter_add distributes over splits of the id list.
#[test]
fn prop_gather_scatter_roundtrip_and_linearity() {
    check("gather-scatter", 200, |rng| {
        let tr = MemTraffic::default();
        let n = 2 + rng.below(40);
        let cols = 1 + rng.below(8);
        let mut sb = StateBuffer::new(n, cols);
        let m = 1 + rng.below(n);
        // distinct ids
        let mut ids: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(m);
        let block: Vec<f32> = (0..m * cols).map(|i| i as f32).collect();
        sb.scatter(&ids, &block, &tr);
        let opt_ids: Vec<Option<u32>> = ids.iter().map(|&v| Some(v)).collect();
        let mut back = vec![-1.0f32; m * cols];
        sb.gather(&opt_ids, &mut back, &tr);
        assert_eq!(back, block);

        // scatter_add linearity: adding in two halves == adding all once
        let mut a = StateBuffer::new(n, cols);
        let mut b = StateBuffer::new(n, cols);
        let half = m / 2;
        a.scatter_add(&opt_ids, &block, &tr);
        b.scatter_add(&opt_ids[..half], &block[..half * cols], &tr);
        b.scatter_add(&opt_ids[half..], &block[half * cols..], &tr);
        for v in 0..n {
            assert_eq!(a.row(v), b.row(v));
        }
    });
}

/// Prop. 2 invariants hold for arbitrary hidden sizes: eager ops never
/// descend from gather; lazy ops never feed scatter; the two primitives'
/// adjoints swap (gather<->scatter, pull<->push).
#[test]
fn prop_program_analysis_invariants() {
    use cavs::models::Cell;
    check("prop2-invariants", 60, |rng| {
        let h = 1 + rng.below(64);
        for cell in [Cell::Lstm, Cell::TreeLstm, Cell::TreeFc] {
            let p = cell.program(h);
            let a = p.analyze();
            // reachability recomputed naively here as the oracle
            let n = p.nodes.len();
            let mut below_gather = vec![false; n];
            for (i, node) in p.nodes.iter().enumerate() {
                if matches!(node.kind, OpKind::Gather { .. }) {
                    below_gather[i] = true;
                }
                if node.ins.iter().any(|&j| below_gather[j]) {
                    below_gather[i] = true;
                }
            }
            for &e in &a.eager {
                assert!(!below_gather[e], "{}: eager op {e} depends on gather", p.name);
            }
            let mut feeds_scatter = vec![false; n];
            for i in (0..n).rev() {
                if matches!(p.nodes[i].kind, OpKind::Scatter) {
                    feeds_scatter[i] = true;
                }
                if feeds_scatter[i] {
                    for &j in &p.nodes[i].ins {
                        feeds_scatter[j] = true;
                    }
                }
            }
            for &l in &a.lazy {
                assert!(!feeds_scatter[l], "{}: lazy op {l} feeds scatter", p.name);
            }
        }
    });
}

/// The SST s-expression parser round-trips structure: parse -> regenerate
/// -> parse produces an identical graph.
#[test]
fn prop_sexpr_parse_roundtrip() {
    use cavs::graph::parse::parse_sst;
    check("sexpr-roundtrip", 100, |rng| {
        let leaves = 1 + rng.below(12);
        let g = synth::random_binary_tree(rng, 20, leaves, 5);
        // serialize back to an s-expression (post-order ids)
        fn ser(g: &InputGraph, v: usize, out: &mut String) {
            let cs = &g.children[v];
            if cs.is_empty() {
                out.push_str(&format!("(1 w{})", g.tokens[v]));
            } else {
                out.push_str("(1 ");
                ser(g, cs[0] as usize, out);
                out.push(' ');
                ser(g, cs[1] as usize, out);
                out.push(')');
            }
        }
        let mut text = String::new();
        let root = g.roots()[0] as usize;
        ser(&g, root, &mut text);
        let parsed = parse_sst(&text, |w| w[1..].parse().unwrap()).unwrap();
        assert_eq!(parsed.n(), g.n());
        assert_eq!(parsed.n_leaves(), g.n_leaves());
        assert_eq!(parsed.max_depth(), g.max_depth());
        // leaf multiset of tokens must match
        let mut a: Vec<i32> =
            g.tokens.iter().copied().filter(|&t| t >= 0).collect();
        let mut b: Vec<i32> =
            parsed.tokens.iter().copied().filter(|&t| t >= 0).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    });
}

/// The parallel engine path (`threads > 1`) produces **bitwise identical**
/// forward states, backward state gradients, input-table gradients, and
/// traffic counters to the sequential path on random synthetic graph
/// batches. This is the equivalence contract of exec::parallel: forward
/// writes shard by destination row, backward accumulations shard by
/// destination owner so contributions apply in sequential order.
#[test]
fn prop_parallel_frontier_bitwise_matches_sequential() {
    check("parallel-equivalence", 40, |rng| {
        let graphs = random_graphs(rng);
        let arity = graphs
            .iter()
            .flat_map(|g| g.children.iter())
            .map(Vec::len)
            .max()
            .unwrap_or(1)
            .max(1);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, arity);
        let tasks = schedule(&batch, Policy::Batched, BUCKETS);

        let h = 1 + rng.below(8);
        let vocab = 20usize;
        let cell = HostTreeFc::random(h, arity, rng);
        let xtable: Vec<f32> =
            (0..vocab * h).map(|_| rng.normal_f32(0.5)).collect();

        let base = run_host_frontier(&batch, &tasks, &cell, &xtable, 1, true);
        for threads in [2usize, 3, 8] {
            let run =
                run_host_frontier(&batch, &tasks, &cell, &xtable, threads, true);
            assert_eq!(
                base.states.as_slice(),
                run.states.as_slice(),
                "forward states diverge at threads={threads}"
            );
            assert_eq!(
                base.grads.as_ref().unwrap().as_slice(),
                run.grads.as_ref().unwrap().as_slice(),
                "state gradients diverge at threads={threads}"
            );
            assert_eq!(
                base.x_grads, run.x_grads,
                "input-table gradients diverge at threads={threads}"
            );
            assert_eq!(
                (base.traffic_bytes, base.traffic_ops),
                (run.traffic_bytes, run.traffic_ops),
                "traffic accounting diverges at threads={threads}"
            );
        }
    });
}

/// The three executors — sequential, scoped spawn-per-primitive (the
/// pre-pool baseline), and the persistent worker pool — produce **bitwise
/// identical** forward states, backward gradients, input-table gradients
/// and traffic counters on random graph batches at every thread count:
/// they execute the same shard plan, only the threads running the shards
/// differ. This is the contract that let the pool replace the scoped
/// spawns without touching numerics.
#[test]
fn prop_pool_scoped_sequential_bitwise_equivalent() {
    check("executor-equivalence", 20, |rng| {
        let graphs = random_graphs(rng);
        let arity = graphs
            .iter()
            .flat_map(|g| g.children.iter())
            .map(Vec::len)
            .max()
            .unwrap_or(1)
            .max(1);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, arity);
        let tasks = schedule(&batch, Policy::Batched, BUCKETS);
        let h = 1 + rng.below(6);
        let vocab = 20usize;
        let cell = HostTreeFc::random(h, arity, rng);
        let xtable: Vec<f32> =
            (0..vocab * h).map(|_| rng.normal_f32(0.5)).collect();

        let mut seq = HostFrontier::new();
        seq.run(&batch, &tasks, &cell, &xtable, Sharder::Sequential, true);
        for threads in [2usize, 3, 5] {
            let pool = WorkerPool::new(threads);
            for (mode, ex) in [
                ("scoped", Sharder::Scoped { threads }),
                ("pool", Sharder::Pool(&pool)),
            ] {
                let mut r = HostFrontier::new();
                r.run(&batch, &tasks, &cell, &xtable, ex, true);
                assert_eq!(
                    seq.states().as_slice(),
                    r.states().as_slice(),
                    "{mode} t={threads}: forward states diverge"
                );
                assert_eq!(
                    seq.grads().unwrap().as_slice(),
                    r.grads().unwrap().as_slice(),
                    "{mode} t={threads}: state gradients diverge"
                );
                assert_eq!(
                    seq.x_grads(),
                    r.x_grads(),
                    "{mode} t={threads}: input-table gradients diverge"
                );
                assert_eq!(
                    (seq.traffic_bytes(), seq.traffic_ops()),
                    (r.traffic_bytes(), r.traffic_ops()),
                    "{mode} t={threads}: traffic accounting diverges"
                );
                assert_eq!(
                    seq.padded_rows(),
                    r.padded_rows(),
                    "{mode} t={threads}: padding observation diverges"
                );
            }
        }
    });
}

/// Arena recycling is invisible: one `HostFrontier` reused across
/// consecutive random batches (its block arenas, index plans and shard
/// scratch carrying over) produces exactly the results of a fresh
/// executor per batch. This is the safety half of the zero-steady-state-
/// allocation design — stale capacity can never leak into results.
#[test]
fn prop_arena_recycling_is_result_invariant() {
    check("scratch-reuse", 8, |rng| {
        let pool = WorkerPool::new(3);
        let mut reused = HostFrontier::new();
        for _round in 0..3 {
            let graphs = random_graphs(rng);
            let arity = graphs
                .iter()
                .flat_map(|g| g.children.iter())
                .map(Vec::len)
                .max()
                .unwrap_or(1)
                .max(1);
            let refs: Vec<&InputGraph> = graphs.iter().collect();
            let batch = GraphBatch::new(&refs, arity);
            let tasks = schedule(&batch, Policy::Batched, BUCKETS);
            let h = 1 + rng.below(6);
            let cell = HostTreeFc::random(h, arity, rng);
            let xtable: Vec<f32> =
                (0..20 * h).map(|_| rng.normal_f32(0.5)).collect();

            let ex = Sharder::Pool(&pool);
            let mut fresh = HostFrontier::new();
            fresh.run(&batch, &tasks, &cell, &xtable, ex, true);
            reused.run(&batch, &tasks, &cell, &xtable, ex, true);
            assert_eq!(fresh.states().as_slice(), reused.states().as_slice());
            assert_eq!(
                fresh.grads().unwrap().as_slice(),
                reused.grads().unwrap().as_slice()
            );
            assert_eq!(fresh.x_grads(), reused.x_grads());
            assert_eq!(fresh.traffic_bytes(), reused.traffic_bytes());
            assert_eq!(fresh.traffic_ops(), reused.traffic_ops());
            assert_eq!(fresh.padded_rows(), reused.padded_rows());
        }
    });
}

/// `ScheduleStats.padded_rows` is a function of (batch, policy, buckets)
/// alone: the worker-thread count shards rows *within* tasks and must
/// never change the padding accounting. `HostRun.padded_rows` is counted
/// by the sharded row loops at execution time (bucket − rows actually
/// evaluated), so a shard that dropped or duplicated rows would break
/// the equality below.
#[test]
fn padded_rows_invariant_under_thread_count() {
    let mut rng = Rng::new(17);
    let graphs = random_graphs(&mut rng);
    let arity = graphs
        .iter()
        .flat_map(|g| g.children.iter())
        .map(Vec::len)
        .max()
        .unwrap_or(1)
        .max(1);
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let batch = GraphBatch::new(&refs, arity);
    let tasks = schedule(&batch, Policy::Batched, BUCKETS);
    let expect = stats(&tasks).padded_rows;

    let h = 4;
    let cell = HostTreeFc::random(h, arity, &mut rng);
    let xtable: Vec<f32> = (0..20 * h).map(|_| rng.normal_f32(0.5)).collect();
    for threads in [1usize, 2, 4, 16] {
        let run = run_host_frontier(&batch, &tasks, &cell, &xtable, threads, false);
        assert_eq!(
            run.padded_rows, expect,
            "padded_rows changed under threads={threads}"
        );
    }
}

/// Bucket selection: smallest bucket >= m, never smaller than m unless m
/// exceeds the maximum (then chunking applies upstream).
#[test]
fn prop_bucket_selection() {
    check("buckets", 300, |rng| {
        let m = 1 + rng.below(5000);
        let b = cavs::util::bucket_for(m, 1024);
        if m <= 1024 {
            assert!(b >= m, "bucket {b} < m {m}");
            assert!(b < 2 * m, "bucket {b} wastes more than 2x for m {m}");
            assert!(b.is_power_of_two());
        } else {
            assert_eq!(b, 1024);
        }
    });
}

/// The serve batch former's merged `GraphBatch` over a random request set
/// is bitwise identical to the offline `graph::batch` merge of the same
/// samples — and re-merging through the recycled arenas changes nothing.
#[test]
fn prop_serve_merge_bitwise_matches_offline_merge() {
    use cavs::serve::{BatchFormer, Fixed, Request, RequestQueue};
    use std::time::Duration;

    check("serve-merge", 80, |rng| {
        let graphs = random_graphs(rng);
        let arity = graphs
            .iter()
            .flat_map(|g| g.children.iter())
            .map(Vec::len)
            .max()
            .unwrap_or(1)
            .max(1);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let offline = GraphBatch::new(&refs, arity);

        let q = RequestQueue::bounded(graphs.len());
        for (id, g) in graphs.iter().enumerate() {
            q.try_enqueue(Request::new(id as u64, g.clone()).unwrap())
                .unwrap();
        }
        let mut former = BatchFormer::new(Fixed {
            max_batch: graphs.len(),
            max_delay: Duration::ZERO,
        });
        let k = former.form(&q);
        assert_eq!(k, graphs.len(), "one batch holds the whole request set");

        let mut merged = GraphBatch::empty(arity);
        for round in 0..2 {
            // round 1 re-merges through the already-grown arenas: the
            // recycled merge must stay bitwise identical to the fresh one
            merged.merge_indexed(k, arity, |i| former.requests()[i].merge_item());
            assert_eq!(merged, offline, "round {round}");
        }
    });
}

/// Every enqueued request gets exactly one response — no drops, no
/// duplicates — across **all three batching policies**, deadline settings
/// (including a zero deadline), batch sizes, queue capacities and thread
/// counts, with admission control (`Full`) handled by draining the
/// server.
#[test]
fn prop_serve_every_request_answered_exactly_once() {
    use cavs::serve::{
        HostExec, PolicyKind, Request, RequestQueue, ServeConfig, Server,
    };

    check("serve-exactly-once", 25, |rng| {
        let graphs = random_graphs(rng);
        let n = 4 + rng.below(28);
        let max_batch = 1 + rng.below(8);
        let deadline_ms = match rng.below(3) {
            0 => 0.0,
            1 => 0.2,
            _ => 2.0,
        };
        let cap = 1 + rng.below(n);
        let threads = 1 + rng.below(3);
        let cfg = ServeConfig {
            policy: PolicyKind::ALL[rng.below(3)],
            max_batch,
            deadline_ms,
            queue_cap: cap,
            ..ServeConfig::default()
        };
        let mut server = Server::with_policy(
            HostExec::tree_fc(4, 2, 20, threads, 7),
            cfg.make_policy(),
        );
        // capacity-only admission: the exactly-once invariant must hold
        // for every policy even without deadline shedding in play (the
        // shed path has its own accounting test in serve_policy.rs)
        let q = RequestQueue::bounded(cap);
        let mut got = vec![0u32; n];
        let mut on_resp = |resp: cavs::serve::Response| {
            assert!(resp.prediction.score.is_finite());
            got[resp.id() as usize] += 1;
        };
        for id in 0..n as u64 {
            let g = graphs[id as usize % graphs.len()].clone();
            let mut req = Request::new(id, g).unwrap();
            // admission control under a small queue: serve a batch to
            // free capacity, then resubmit — nothing may be dropped
            loop {
                match q.try_enqueue(req) {
                    Ok(()) => break,
                    Err((back, _full)) => {
                        req = back;
                        assert!(server.step(&q, &mut on_resp).unwrap());
                    }
                }
            }
        }
        q.close();
        while server.step(&q, &mut on_resp).unwrap() {}
        assert!(
            got.iter().all(|&c| c == 1),
            "response multiplicity violated: {got:?}"
        );
        assert_eq!(server.metrics.n_responses(), n);
    });
}

/// The serve planner (recycled depth-level chunking) and the offline
/// scheduler produce forward-equivalent plans: identical per-vertex
/// states out of the host frontier, identical padding totals.
#[test]
fn prop_serve_plan_forward_matches_scheduler() {
    use cavs::serve::BatchPlan;

    check("serve-plan", 60, |rng| {
        let graphs = random_graphs(rng);
        let arity = graphs
            .iter()
            .flat_map(|g| g.children.iter())
            .map(Vec::len)
            .max()
            .unwrap_or(1)
            .max(1);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, arity);
        let sched = schedule(&batch, Policy::Batched, BUCKETS);
        let mut planner = BatchPlan::new();
        let planned = planner.plan(&batch, BUCKETS).to_vec();
        assert_eq!(
            stats(&planned).padded_rows,
            stats(&sched).padded_rows,
            "identical bucket chunking"
        );

        let h = 4;
        let cell = HostTreeFc::random(h, arity, rng);
        let xtable: Vec<f32> =
            (0..20 * h).map(|_| rng.normal_f32(0.5)).collect();
        let a = run_host_frontier(&batch, &sched, &cell, &xtable, 1, false);
        let b = run_host_frontier(&batch, &planned, &cell, &xtable, 1, false);
        assert_eq!(
            a.states.as_slice(),
            b.states.as_slice(),
            "planner and scheduler must compute identical states"
        );
    });
}

/// The compiled host path — `Program::optimize()`'s folded views, wide
/// gate GEMMs, fused elementwise sweeps, executed per frontier level by
/// the `LevelCell` hooks — is **bitwise identical** to the reference
/// per-row interpreter for every registered cell: forward states,
/// backward state gradients, input-table gradients, parameter gradients,
/// traffic accounting and padding, at thread counts {1, 2, 4}. This is
/// the optimizer's acceptance contract: the speedup may never move a
/// single output bit.
#[test]
fn prop_optimized_matches_unoptimized_bitwise() {
    use cavs::models::CellSpec;

    check("opt-equivalence", 10, |rng| {
        let vocab = 20usize;
        let h = 1 + rng.below(6);
        for cell in [
            "lstm",
            "treelstm",
            "treefc",
            "gru",
            "cstreelstm",
            "gnn",
            "attnseq2seq",
        ] {
            let spec = CellSpec::lookup(cell, h).unwrap();
            let arity = spec.arity();
            // arity-1 cells batch chains; the DAG cells batch their own
            // multi-parent workloads; tree cells batch the mixed set
            let graphs: Vec<InputGraph> = if arity == 1 {
                let k = 1 + rng.below(6);
                (0..k)
                    .map(|_| {
                        let len = 1 + rng.below(10);
                        let toks: Vec<i32> =
                            (0..len).map(|_| rng.below(vocab) as i32).collect();
                        let labs = vec![-1; len];
                        InputGraph::chain(&toks, &labs)
                    })
                    .collect()
            } else if cell == "gnn" {
                let k = 1 + rng.below(4);
                (0..k)
                    .map(|_| {
                        let layers = 1 + rng.below(3);
                        let width = 2 + rng.below(3);
                        synth::gnn_dag(rng, vocab, layers, width, 4, 5)
                    })
                    .collect()
            } else if cell == "attnseq2seq" {
                let k = 1 + rng.below(4);
                (0..k)
                    .map(|_| synth::seq2seq_copy(rng, vocab, 3, 8, 3))
                    .collect()
            } else {
                random_graphs(rng)
            };
            let refs: Vec<&InputGraph> = graphs.iter().collect();
            let batch = GraphBatch::new(&refs, arity);
            let tasks = schedule(&batch, Policy::Batched, BUCKETS);
            let xtable: Vec<f32> =
                (0..vocab * h).map(|_| rng.normal_f32(0.5)).collect();

            // identical parameter stream on both sides
            let mut prng = Rng::new(1000 + h as u64);
            let reference = spec.random_cell_unoptimized(&mut prng, 0.2).unwrap();
            let mut prng = Rng::new(1000 + h as u64);
            let optimized = spec.random_cell(&mut prng, 0.2).unwrap();

            let base =
                run_host_frontier(&batch, &tasks, &reference, &xtable, 1, true);
            for threads in [1usize, 2, 4] {
                let r = run_host_frontier(
                    &batch, &tasks, &optimized, &xtable, threads, true,
                );
                assert_eq!(
                    base.states.as_slice(),
                    r.states.as_slice(),
                    "{cell} h={h} t={threads}: forward states diverge"
                );
                assert_eq!(
                    base.grads.as_ref().unwrap().as_slice(),
                    r.grads.as_ref().unwrap().as_slice(),
                    "{cell} h={h} t={threads}: state gradients diverge"
                );
                assert_eq!(
                    base.x_grads, r.x_grads,
                    "{cell} h={h} t={threads}: input-table gradients diverge"
                );
                assert_eq!(
                    base.param_grads, r.param_grads,
                    "{cell} h={h} t={threads}: parameter gradients diverge"
                );
                assert_eq!(
                    (base.traffic_bytes, base.traffic_ops),
                    (r.traffic_bytes, r.traffic_ops),
                    "{cell} h={h} t={threads}: traffic accounting diverges"
                );
                assert_eq!(
                    base.padded_rows, r.padded_rows,
                    "{cell} h={h} t={threads}: padding observation diverges"
                );
            }
        }
    });
}

/// `--set math=fast` swaps the compiled path's sigmoid/tanh for the
/// vectorized polynomial kernels (DESIGN.md §11). Outputs are no longer
/// bitwise against exact mode, but on whole frontier batches they must
/// stay within a tight relative bound — and fast mode must remain
/// **bitwise thread-count invariant against itself**, since the kernel
/// table changes the math, never the shard plan or reduction order.
#[test]
fn prop_fast_math_close_to_exact_and_thread_invariant() {
    use cavs::exec::MathMode;
    use cavs::models::CellSpec;

    check("fast-math", 10, |rng| {
        let vocab = 20usize;
        let h = 2 + rng.below(7);
        for cell in ["gru", "treelstm"] {
            let spec = CellSpec::lookup(cell, h).unwrap();
            let arity = spec.arity();
            let graphs: Vec<InputGraph> = if arity == 1 {
                let k = 1 + rng.below(6);
                (0..k)
                    .map(|_| {
                        let len = 1 + rng.below(10);
                        let toks: Vec<i32> =
                            (0..len).map(|_| rng.below(vocab) as i32).collect();
                        let labs = vec![-1; len];
                        InputGraph::chain(&toks, &labs)
                    })
                    .collect()
            } else {
                random_graphs(rng)
            };
            let refs: Vec<&InputGraph> = graphs.iter().collect();
            let batch = GraphBatch::new(&refs, arity);
            let tasks = schedule(&batch, Policy::Batched, BUCKETS);
            let xtable: Vec<f32> =
                (0..vocab * h).map(|_| rng.normal_f32(0.5)).collect();

            // identical parameter stream on both sides
            let mut prng = Rng::new(2000 + h as u64);
            let exact = spec.random_cell(&mut prng, 0.2).unwrap();
            let mut prng = Rng::new(2000 + h as u64);
            let mut fast = spec.random_cell(&mut prng, 0.2).unwrap();
            fast.set_math(MathMode::Fast);

            let base = run_host_frontier(&batch, &tasks, &exact, &xtable, 1, true);
            let f1 = run_host_frontier(&batch, &tasks, &fast, &xtable, 1, true);
            let close = |a: &[f32], b: &[f32], what: &str| {
                for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
                    let tol = 1e-3 * x.abs().max(y.abs()).max(1.0);
                    assert!(
                        (x - y).abs() <= tol,
                        "{cell} h={h} {what}[{i}]: fast {y} vs exact {x} (tol {tol})"
                    );
                }
            };
            close(base.states.as_slice(), f1.states.as_slice(), "states");
            close(
                base.grads.as_ref().unwrap().as_slice(),
                f1.grads.as_ref().unwrap().as_slice(),
                "grads",
            );

            for threads in [2usize, 4] {
                let ft =
                    run_host_frontier(&batch, &tasks, &fast, &xtable, threads, true);
                assert_eq!(
                    f1.states.as_slice(),
                    ft.states.as_slice(),
                    "{cell} h={h} t={threads}: fast states not thread-invariant"
                );
                assert_eq!(
                    f1.grads.as_ref().unwrap().as_slice(),
                    ft.grads.as_ref().unwrap().as_slice(),
                    "{cell} h={h} t={threads}: fast grads not thread-invariant"
                );
                assert_eq!(
                    f1.x_grads, ft.x_grads,
                    "{cell} h={h} t={threads}: fast x-grads not thread-invariant"
                );
                assert_eq!(
                    f1.param_grads, ft.param_grads,
                    "{cell} h={h} t={threads}: fast param grads not thread-invariant"
                );
            }
        }
    });
}

/// Observability is a pure observer (DESIGN.md §12): running the exact
/// same frontier batch with the span tracer and the per-op-class
/// profiler enabled produces **bitwise identical** forward states,
/// backward gradients, input-table gradients, parameter gradients and
/// traffic accounting to the untraced run, at every thread count. The
/// instrumentation may read clocks and fill rings, but it may never
/// touch a result bit.
#[test]
fn prop_observability_never_perturbs_results() {
    use cavs::models::CellSpec;

    check("obs-transparent", 10, |rng| {
        let vocab = 20usize;
        let h = 1 + rng.below(6);
        for cell in ["gru", "treelstm"] {
            let spec = CellSpec::lookup(cell, h).unwrap();
            let arity = spec.arity();
            let graphs: Vec<InputGraph> = if arity == 1 {
                let k = 1 + rng.below(6);
                (0..k)
                    .map(|_| {
                        let len = 1 + rng.below(10);
                        let toks: Vec<i32> =
                            (0..len).map(|_| rng.below(vocab) as i32).collect();
                        let labs = vec![-1; len];
                        InputGraph::chain(&toks, &labs)
                    })
                    .collect()
            } else {
                random_graphs(rng)
            };
            let refs: Vec<&InputGraph> = graphs.iter().collect();
            let batch = GraphBatch::new(&refs, arity);
            let tasks = schedule(&batch, Policy::Batched, BUCKETS);
            let xtable: Vec<f32> =
                (0..vocab * h).map(|_| rng.normal_f32(0.5)).collect();
            let mut prng = Rng::new(3000 + h as u64);
            let pc = spec.random_cell(&mut prng, 0.2).unwrap();

            cavs::obs::trace::set_enabled(false);
            cavs::obs::profile::set_enabled(false);
            let base = run_host_frontier(&batch, &tasks, &pc, &xtable, 1, true);

            cavs::obs::trace::set_ring_capacity(64);
            cavs::obs::trace::set_enabled(true);
            cavs::obs::profile::set_enabled(true);
            let spans_before = cavs::obs::trace::total_recorded();
            for threads in [1usize, 2, 4] {
                let r =
                    run_host_frontier(&batch, &tasks, &pc, &xtable, threads, true);
                assert_eq!(
                    base.states.as_slice(),
                    r.states.as_slice(),
                    "{cell} h={h} t={threads}: tracing perturbed forward states"
                );
                assert_eq!(
                    base.grads.as_ref().unwrap().as_slice(),
                    r.grads.as_ref().unwrap().as_slice(),
                    "{cell} h={h} t={threads}: tracing perturbed state gradients"
                );
                assert_eq!(
                    base.x_grads, r.x_grads,
                    "{cell} h={h} t={threads}: tracing perturbed x-grads"
                );
                assert_eq!(
                    base.param_grads, r.param_grads,
                    "{cell} h={h} t={threads}: tracing perturbed param grads"
                );
                assert_eq!(
                    (base.traffic_bytes, base.traffic_ops),
                    (r.traffic_bytes, r.traffic_ops),
                    "{cell} h={h} t={threads}: tracing perturbed traffic"
                );
            }
            cavs::obs::trace::set_enabled(false);
            cavs::obs::profile::set_enabled(false);
            assert!(
                cavs::obs::trace::total_recorded() > spans_before,
                "{cell} h={h}: the traced runs recorded no spans"
            );
        }
    });
}

/// The Program interpreter is **bitwise identical** to the hand-written
/// host cells on the same weights: both sides perform the same f32
/// operations in the same order (matmul accumulation order, add/bias
/// association, gate math). Forward for LSTM; forward + structural
/// backward for Tree-FC — across random shapes, batches and thread
/// counts. This is the acceptance gate for the open CellSpec API: a
/// user-defined program computes exactly what a hand-tuned cell would.
#[test]
fn prop_interpreter_matches_hand_written_cells_bitwise() {
    use cavs::vertex::interp::ProgramCell;
    use cavs::vertex::programs::{lstm_program, treefc_program};

    check("interp-equivalence", 25, |rng| {
        let vocab = 20usize;

        // ---- Tree-FC: forward + backward ------------------------------
        let graphs = random_graphs(rng);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, 2);
        let tasks = schedule(&batch, Policy::Batched, BUCKETS);
        let h = 1 + rng.below(6);
        let hand = HostTreeFc::random(h, 2, rng);
        let interp =
            ProgramCell::new(treefc_program(h), hand.params_vec()).unwrap();
        let xtable: Vec<f32> =
            (0..vocab * h).map(|_| rng.normal_f32(0.5)).collect();
        let a = run_host_frontier(&batch, &tasks, &hand, &xtable, 1, true);
        for threads in [1usize, 3] {
            let b =
                run_host_frontier(&batch, &tasks, &interp, &xtable, threads, true);
            assert_eq!(
                a.states.as_slice(),
                b.states.as_slice(),
                "treefc forward diverges (threads={threads})"
            );
            assert_eq!(
                a.grads.as_ref().unwrap().as_slice(),
                b.grads.as_ref().unwrap().as_slice(),
                "treefc state gradients diverge (threads={threads})"
            );
            assert_eq!(
                a.x_grads, b.x_grads,
                "treefc input-table gradients diverge (threads={threads})"
            );
            assert_eq!(a.padded_rows, b.padded_rows);
            // the interpreter additionally produces parameter gradients;
            // they must be thread-count invariant (sequential row order)
            let pg = b.param_grads.as_ref().unwrap();
            assert_eq!(pg.len(), 4, "Wx, Wl, Wr, b");
            assert!(pg.iter().flat_map(|g| g.iter()).all(|v| v.is_finite()));
        }
        let pg1 = run_host_frontier(&batch, &tasks, &interp, &xtable, 1, true)
            .param_grads
            .unwrap();
        let pg4 = run_host_frontier(&batch, &tasks, &interp, &xtable, 4, true)
            .param_grads
            .unwrap();
        assert_eq!(pg1, pg4, "param grads diverge across thread counts");

        // ---- LSTM: forward (hand cell is forward-only) ----------------
        let k = 1 + rng.below(6);
        let chains: Vec<InputGraph> = (0..k)
            .map(|_| {
                let len = 1 + rng.below(10);
                let toks: Vec<i32> =
                    (0..len).map(|_| rng.below(vocab) as i32).collect();
                let labs = vec![-1; len];
                InputGraph::chain(&toks, &labs)
            })
            .collect();
        let crefs: Vec<&InputGraph> = chains.iter().collect();
        let cbatch = GraphBatch::new(&crefs, 1);
        let ctasks = schedule(&cbatch, Policy::Batched, BUCKETS);
        let hl = 1 + rng.below(5);
        let hand = HostLstm::random(hl, rng);
        let interp =
            ProgramCell::new(lstm_program(hl), hand.params_vec()).unwrap();
        let xt: Vec<f32> =
            (0..vocab * hl).map(|_| rng.normal_f32(0.5)).collect();
        let a = run_host_frontier(&cbatch, &ctasks, &hand, &xt, 1, false);
        for threads in [1usize, 4] {
            let b = run_host_frontier(&cbatch, &ctasks, &interp, &xt, threads, false);
            assert_eq!(
                a.states.as_slice(),
                b.states.as_slice(),
                "lstm forward diverges (threads={threads})"
            );
        }
    });
}

// ------------------------------------------------------------------------
// Soundness verifier (DESIGN.md §13): every plan the scheduler emits must
// pass the full disjointness sweep, and randomly corrupted plans/layouts
// must always be rejected.

/// Whatever the scheduler produces for arbitrary graph mixes passes the
/// full `cavs check` plan sweep, at every thread count.
#[test]
fn prop_plan_sweep_accepts_scheduler_output() {
    use cavs::analysis::plan::check_cell_plan;
    check("plan-sweep-accepts", 100, |rng| {
        let graphs = random_graphs(rng);
        let arity = graphs
            .iter()
            .flat_map(|g| g.children.iter())
            .map(Vec::len)
            .max()
            .unwrap_or(1)
            .max(1);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, arity);
        let tasks = schedule(&batch, Policy::Batched, BUCKETS);
        let levels = frontier_levels(&batch);
        let threads = [1, 1 + rng.below(7), 1 + rng.below(15)];
        let state_cols = 1 + rng.below(32);
        let rep =
            check_cell_plan(&batch, &tasks, &levels, state_cols, &threads)
                .expect("scheduler output must be sound");
        assert_eq!(rep.vertices, batch.n_vertices);
        assert_eq!(rep.tasks, tasks.len());
        assert_eq!(rep.levels, levels.len());
        assert!(rep.intervals > 0);
    });
}

/// Randomly corrupting a valid plan (duplicated vertex, merged levels,
/// dropped level, reordered tasks, shrunken bucket) is always caught by
/// the plan pass — never silently accepted.
#[test]
fn prop_corrupted_plans_are_rejected() {
    use cavs::analysis::plan::{check_levels, check_tasks};
    check("plan-corruption-rejected", 120, |rng| {
        let graphs = random_graphs(rng);
        let arity = graphs
            .iter()
            .flat_map(|g| g.children.iter())
            .map(Vec::len)
            .max()
            .unwrap_or(1)
            .max(1);
        let refs: Vec<&InputGraph> = graphs.iter().collect();
        let batch = GraphBatch::new(&refs, arity);
        let tasks = schedule(&batch, Policy::Batched, BUCKETS);
        let levels = frontier_levels(&batch);
        check_levels(&batch, &levels).expect("baseline levels sound");
        check_tasks(&batch, &tasks).expect("baseline tasks sound");

        match rng.below(4) {
            0 => {
                // duplicate a vertex into another (or the same) level
                let mut bad = levels.clone();
                let li = rng.below(bad.len());
                let v = bad[li][rng.below(bad[li].len())];
                let lj = rng.below(bad.len());
                bad[lj].push(v);
                assert!(check_levels(&batch, &bad).is_err());
            }
            1 => {
                // merge two adjacent levels (parent joins its child's
                // level) — needs a second level to merge
                if levels.len() >= 2 {
                    let mut bad = levels.clone();
                    let l1 = bad.remove(1);
                    bad[0].extend(l1);
                    assert!(check_levels(&batch, &bad).is_err());
                }
            }
            2 => {
                // drop the deepest level entirely
                let mut bad = levels.clone();
                bad.pop();
                assert!(check_levels(&batch, &bad).is_err());
            }
            _ => {
                // task corruption: reversal breaks dependencies when the
                // plan is deeper than one level; otherwise shrink a
                // bucket below its task size
                if levels.len() >= 2 {
                    let mut bad = tasks.clone();
                    bad.reverse();
                    assert!(check_tasks(&batch, &bad).is_err());
                } else {
                    let mut bad = tasks.clone();
                    let ti = rng.below(bad.len());
                    bad[ti].bucket = bad[ti].m() - 1;
                    assert!(check_tasks(&batch, &bad).is_err());
                }
            }
        }
    });
}

/// Every registered cell's compiled layout verifies at arbitrary widths,
/// and randomly corrupting the layout record (aliased adjoints, broken
/// stride, cyclic or out-of-bounds alias chains) is always rejected.
#[test]
fn prop_corrupted_layouts_are_rejected() {
    use cavs::vertex::opt::Alloc;
    use cavs::vertex::registry::{registered_cells, CellSpec};
    check("layout-corruption-rejected", 80, |rng| {
        let cells = registered_cells();
        let name = &cells[rng.below(cells.len())];
        let h = [4usize, 8, 12, 16][rng.below(4)];
        let spec = CellSpec::lookup(name, h).expect("registered cell");
        let good = spec.opt_program();
        let rep = good.verify().expect("registered layout must verify");
        assert!(rep.nodes > 0);

        let mut bad = good.clone();
        match rng.below(4) {
            0 => {
                // alias two adjoint slots: pick two distinct real nodes
                let real: Vec<usize> = (0..bad.nodes.len())
                    .filter(|&i| bad.aoff[i] != usize::MAX)
                    .collect();
                if real.len() >= 2 {
                    let a = real[rng.below(real.len())];
                    let mut b = real[rng.below(real.len())];
                    if a == b {
                        b = if a == real[0] { real[1] } else { real[0] };
                    }
                    bad.aoff[a] = bad.aoff[b];
                    assert!(bad.verify().is_err(), "{name} h={h}: aliased adjoints accepted");
                }
            }
            1 => {
                // break the 16-float level-execution row pitch
                bad.tape_stride += 1;
                assert!(bad.verify().is_err(), "{name} h={h}: bad stride accepted");
            }
            2 => {
                // make an alias chain cyclic: a view that views itself
                let view: Vec<usize> = (0..bad.nodes.len())
                    .filter(|&i| matches!(bad.alloc[i], Alloc::At(..)))
                    .collect();
                if let Some(&i) =
                    view.get(rng.below(view.len().max(1)))
                {
                    if let Alloc::At(_, off) = bad.alloc[i] {
                        bad.alloc[i] = Alloc::At(i, off);
                        assert!(bad.verify().is_err(), "{name} h={h}: alias cycle accepted");
                    }
                }
            }
            _ => {
                // push a view far out of its parent's backing region
                let view: Vec<usize> = (0..bad.nodes.len())
                    .filter(|&i| matches!(bad.alloc[i], Alloc::At(..)))
                    .collect();
                if let Some(&i) =
                    view.get(rng.below(view.len().max(1)))
                {
                    if let Alloc::At(p, _) = bad.alloc[i] {
                        bad.alloc[i] = Alloc::At(p, bad.tape_cols + 1);
                        assert!(bad.verify().is_err(), "{name} h={h}: oob view accepted");
                    }
                }
            }
        }
    });
}
