// Smoke: HLO-text artifact -> PJRT compile -> execute round trip.
use cavs::runtime::{Arg, Runtime};

#[macro_use]
mod common;
use common::artifacts_dir;

#[test]
fn add_artifact_roundtrip() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let a: Vec<f32> = (0..32).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..32).map(|i| 2.0 * i as f32).collect();
    let outs = rt.run_f32("op_add_n32", &[Arg::F32(&a), Arg::F32(&b)]).unwrap();
    assert_eq!(outs.len(), 1);
    let want: Vec<f32> = (0..32).map(|i| 3.0 * i as f32).collect();
    assert_eq!(outs[0], want);
    assert_eq!(rt.stats().executions, 1);
    assert_eq!(rt.stats().compiles, 1);
}

#[test]
fn buffer_cached_params() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let a: Vec<f32> = vec![1.0; 32];
    let buf = rt.upload_f32(&a, &[32]).unwrap();
    let b: Vec<f32> = vec![4.0; 32];
    let outs = rt.run_f32("op_mul_n32", &[Arg::Buf(&buf), Arg::F32(&b)]).unwrap();
    assert_eq!(outs[0], vec![4.0f32; 32]);
}
