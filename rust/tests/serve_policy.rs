//! Acceptance tests for the pluggable batching-policy API (DESIGN.md
//! §10): per-policy serving equivalence, deadline shedding accounting,
//! and the agreement policy's padding guarantee. Artifact-free (host
//! executors only), so everything here runs on every push in CI.

use std::time::Duration;

use cavs::exec::parallel::HostTreeFc;
use cavs::graph::InputGraph;
use cavs::serve::{
    Admission, AdmitError, Agreement, Class, Fixed, FormPolicy, HostExec,
    PolicyKind, Request, RequestQueue, ServeConfig, Server, SloDeadlines,
};

/// A star of `leaves` leaves under one root: level widths `[leaves, 1]`.
/// Stars of complementary widths are what the agreement policy pairs to
/// hit the planner's bucket boundaries exactly.
fn star(id: u64, leaves: usize) -> Request {
    let n = leaves + 1;
    let children = (0..n)
        .map(|v| if v == n - 1 { (0..leaves as u32).collect() } else { vec![] })
        .collect();
    let g = InputGraph {
        children,
        tokens: (0..n as i32).collect(),
        labels: vec![-1; n],
        root_label: -1,
    };
    Request::new(id, g).unwrap()
}

/// Serve `reqs` offline (enqueue everything, close, drain) through
/// `policy` and return (total padded rows, responses).
fn serve_offline<P: FormPolicy>(
    policy: P,
    reqs: Vec<Request>,
) -> (u64, usize) {
    let exec = HostExec::tree_fc(4, 8, 40, 1, 7);
    let mut server: Server<HostExec<HostTreeFc>, P> =
        Server::with_policy(exec, policy);
    let q = RequestQueue::bounded(reqs.len().max(1));
    let n = reqs.len();
    for r in reqs {
        q.try_enqueue(r).unwrap();
    }
    q.close();
    let mut served = 0usize;
    server.run(&q, |_| served += 1).unwrap();
    assert_eq!(served, n, "offline serving answers everything");
    (server.metrics.report(1.0).padded_rows, served)
}

#[test]
fn agreement_never_pads_more_rows_than_fixed() {
    // arrival order interleaves 3-leaf and 5-leaf stars so the fixed
    // policy's arrival-order pairs (3,3) and (5,5) round their level-0
    // widths 6 and 10 up to buckets 8 and 16 (2 + 6 padded rows per
    // pair-of-pairs), while the agreement pairing (3,5) hits bucket 8
    // exactly. Same workload, same executor, same batch cap.
    let workload = || -> Vec<Request> {
        (0..16u64)
            .map(|id| star(id, if (id / 2) % 2 == 0 { 3 } else { 5 }))
            .collect()
    };
    let (fixed_pad, _) = serve_offline(
        Fixed { max_batch: 2, max_delay: Duration::ZERO },
        workload(),
    );
    let (agree_pad, _) = serve_offline(
        Agreement::new(2, Duration::ZERO, 8),
        workload(),
    );
    assert!(
        agree_pad <= fixed_pad,
        "agreement padded {agree_pad} rows, fixed {fixed_pad}"
    );
    assert!(
        agree_pad < fixed_pad,
        "this workload is constructed so agreement strictly wins \
         (agreement {agree_pad} vs fixed {fixed_pad})"
    );
}

#[test]
fn deadline_admission_sheds_and_every_request_is_accounted() {
    // the adaptive pairing: deadline-admission queue + adaptive policy.
    // Force a pessimistic service estimate, then offer a mix of
    // interactive (1ms budget — hopeless at 100ms/request) and bulk
    // (5s budget — fine) requests: the interactive tail is shed at
    // admission, everything admitted is answered exactly once, and
    // offered == responses + shed.
    let slo = SloDeadlines {
        interactive: Duration::from_millis(1),
        standard: Duration::from_millis(50),
        bulk: Duration::from_secs(5),
    };
    let q = RequestQueue::with_admission(32, Admission::Deadline { slo });
    q.note_service(0.1); // 100ms/request: interactive SLOs are hopeless
    let exec = HostExec::tree_fc(4, 2, 40, 1, 7);
    let mut server = Server::with_policy(
        exec,
        cavs::serve::Adaptive {
            max_batch: 8,
            base_delay: Duration::ZERO,
            slo,
        },
    );
    let offered = 12u64;
    let mut shed = 0u64;
    let mut admitted = 0u64;
    for id in 0..offered {
        let class = if id % 3 == 0 { Class::Interactive } else { Class::Bulk };
        let r = Request::builder(id, InputGraph::chain(&[1, 2], &[-1, -1]))
            .slo(class)
            .build()
            .unwrap();
        match q.try_enqueue(r) {
            Ok(()) => admitted += 1,
            Err((back, AdmitError::Shed)) => {
                assert_eq!(back.class(), Class::Interactive);
                shed += 1;
            }
            Err((_, e)) => panic!("unexpected admission error {e:?}"),
        }
    }
    assert_eq!(shed, 4, "every interactive request is hopeless");
    q.close();
    server.metrics.add_shed(shed);
    let mut responses = 0u64;
    server.run(&q, |_| responses += 1).unwrap();
    assert_eq!(responses, admitted, "admitted requests answered once");
    assert_eq!(responses + shed, offered, "no request unaccounted");
    let report = server.metrics.report(1.0);
    assert_eq!(report.shed, 4);
    assert_eq!(report.n_responses, admitted);
}

#[test]
fn config_policies_serve_identical_predictions() {
    // the three config-selected (boxed) policies answer the same offline
    // workload with identical scores: batch composition must be
    // invisible to clients
    let graphs = cavs::serve::loadgen::mixed_workload(9, 10, 40, 2);
    let mut per_policy: Vec<Vec<f32>> = Vec::new();
    for kind in PolicyKind::ALL {
        let cfg = ServeConfig {
            policy: kind,
            max_batch: 4,
            deadline_ms: 0.0,
            queue_cap: 32,
            ..ServeConfig::default()
        };
        let exec = HostExec::tree_fc(4, 2, 40, 1, 7);
        let mut server = Server::with_policy(exec, cfg.make_policy());
        let q = cfg.make_queue();
        for (id, g) in graphs.iter().enumerate() {
            q.try_enqueue(Request::new(id as u64, g.clone()).unwrap())
                .unwrap();
        }
        q.close();
        let mut scores = vec![f32::NAN; graphs.len()];
        server
            .run(&q, |r| scores[r.id() as usize] = r.prediction.score)
            .unwrap();
        assert!(
            scores.iter().all(|s| s.is_finite()),
            "{}: every request answered",
            kind.name()
        );
        per_policy.push(scores);
    }
    assert_eq!(per_policy[0], per_policy[1], "agreement matches fixed");
    assert_eq!(per_policy[0], per_policy[2], "adaptive matches fixed");
}
