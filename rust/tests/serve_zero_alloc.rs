//! Counting-allocator proof that the **serve loop** is zero-alloc in
//! steady state under **every shipped batching policy**: once warm, a
//! full serve cycle — enqueue, policy-driven batch forming, recycled
//! `GraphBatch` merge, recycled `BatchPlan` scheduling, forward-only
//! host-frontier execution on the persistent worker pool, response
//! delivery and metric recording — performs **zero** heap allocations,
//! sequential and pooled alike. The `FormPolicy` contract requires
//! policies to recycle their scratch (`Agreement`'s level-width arena,
//! the queue's EWMA atomics), and this test is what holds them to it.
//!
//! This is the serving extension of `rust/tests/zero_alloc.rs` (which
//! proves the same for the training fwd+bwd loop). Like that file, this
//! binary deliberately contains a single test: the allocation counter is
//! process-global, so a sibling test running concurrently would pollute
//! the measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use cavs::exec::parallel::HostTreeFc;
use cavs::graph::InputGraph;
use cavs::serve::loadgen::mixed_workload;
use cavs::serve::{
    Adaptive, Agreement, Fixed, FormPolicy, HostExec, Request, RequestQueue,
    Response, Server, SloDeadlines,
};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

/// Run the warm-up + measured window for one policy/thread combination.
/// `label` names the combination in the failure message.
fn run_policy<P: FormPolicy>(
    policy: P,
    threads: usize,
    graphs: &[InputGraph],
    label: &str,
) {
    let n = graphs.len();
    let exec = HostExec::tree_fc(8, 2, 20, threads, 7);
    let mut server: Server<HostExec<HostTreeFc>, P> =
        Server::with_policy(exec, policy);
    let iters_total = 6usize; // 2 warm-up + 3 measured + 1 slack
    server.metrics.reserve_latencies(n * iters_total);
    let q = RequestQueue::bounded(2 * n);
    let mut idle: Vec<Request> = graphs
        .iter()
        .enumerate()
        .map(|(id, g)| Request::new(id as u64, g.clone()).unwrap())
        .collect();
    let mut responses: Vec<Response> = Vec::with_capacity(n);

    let mut serve_once =
        |server: &mut Server<HostExec<HostTreeFc>, P>,
         idle: &mut Vec<Request>| {
            for req in idle.drain(..) {
                q.try_enqueue(req).expect("queue sized for the set");
            }
            while responses.len() < n {
                let more = server
                    .step(&q, &mut |resp| responses.push(resp))
                    .expect("host serving cannot fail");
                assert!(more, "queue is never closed in this test");
            }
            // recycle every request for the next iteration
            for resp in responses.drain(..) {
                assert!(resp.prediction.score.is_finite());
                idle.push(resp.request);
            }
        };

    // Warm-up: the first iterations grow every arena (former pool,
    // policy scratch, merged batch, plan, frontier blocks, metrics
    // reservoir) to the request set's high-water mark; the second
    // proves it's stable.
    for _ in 0..2 {
        serve_once(&mut server, &mut idle);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..3 {
        serve_once(&mut server, &mut idle);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state serve loop heap-allocated ({label})"
    );
    // sanity: the loop really served everything, 5 iterations' worth
    assert_eq!(server.metrics.n_responses(), 5 * n);
    assert_eq!(idle.len(), n);
}

#[test]
fn steady_state_serve_loop_allocates_nothing() {
    // The canonical mixed tree/sequence request set, recycled through
    // the server every iteration: responses hand each Request (graph +
    // precomputed schedule) back, so the client side allocates nothing
    // either. Zero deadlines keep the loop cut-immediately (no sleeps);
    // generous SLOs keep the adaptive path from ever wanting to shed.
    let n = 12usize;
    let graphs: Vec<InputGraph> = mixed_workload(42, n, 20, 2);

    for threads in [1usize, 2] {
        run_policy(
            Fixed { max_batch: 4, max_delay: Duration::ZERO },
            threads,
            &graphs,
            &format!("fixed, threads={threads}"),
        );
        run_policy(
            Agreement::new(4, Duration::ZERO, 8),
            threads,
            &graphs,
            &format!("agreement, threads={threads}"),
        );
        run_policy(
            Adaptive {
                max_batch: 8,
                base_delay: Duration::ZERO,
                slo: SloDeadlines::default(),
            },
            threads,
            &graphs,
            &format!("adaptive, threads={threads}"),
        );
    }

    // Observability (DESIGN.md §12): the serve stages trace through the
    // same preallocated rings — `form`/`exec`/`respond` guards plus the
    // retroactive per-request `queue` span reuse timestamps the server
    // already takes — so a traced steady-state serve loop still allocates
    // nothing. Rings are created during `run_policy`'s warm-up window.
    cavs::obs::trace::set_ring_capacity(512);
    cavs::obs::trace::set_enabled(true);
    let spans_before = cavs::obs::trace::total_recorded();
    for threads in [1usize, 2] {
        run_policy(
            Fixed { max_batch: 4, max_delay: Duration::ZERO },
            threads,
            &graphs,
            &format!("fixed traced, threads={threads}"),
        );
    }
    cavs::obs::trace::set_enabled(false);
    assert!(
        cavs::obs::trace::total_recorded() > spans_before,
        "the traced serve window recorded no spans"
    );
}
