//! Focused subsystem tests that need the artifact set: runtime error
//! paths, Fold preprocessing plans, monolithic-scan padding accounting,
//! manifest integrity, and engine instrumentation (launch counts /
//! memory-traffic accounting that Tables 1-2 rely on).

use cavs::baselines::fold::Fold;
use cavs::baselines::monolithic::{ScanLm, UnrollMode};
use cavs::exec::{Engine, EngineOpts};
use cavs::graph::{synth, Dataset, InputGraph};
use cavs::models::{Cell, HeadKind, Model};
use cavs::runtime::{Arg, Runtime};
use cavs::scheduler::Policy;
use cavs::util::rng::Rng;

#[macro_use]
mod common;
use common::artifacts_dir;

// ---------------------------------------------------------------------
// runtime / manifest
// ---------------------------------------------------------------------

#[test]
fn runtime_rejects_wrong_arity_and_shape() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let exe = rt.load("op_add_n32").unwrap();
    let a = vec![0.0f32; 32];
    // wrong number of args
    assert!(rt.run(&exe, &[Arg::F32(&a)]).is_err());
    // wrong element count
    let short = vec![0.0f32; 31];
    assert!(rt.run(&exe, &[Arg::F32(&a), Arg::F32(&short)]).is_err());
    // wrong dtype
    let ints = vec![0i32; 32];
    assert!(rt.run(&exe, &[Arg::F32(&a), Arg::I32(&ints)]).is_err());
}

#[test]
fn runtime_unknown_artifact_is_error() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    assert!(rt.load("no_such_artifact").is_err());
}

#[test]
fn executable_cache_compiles_once() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let a = vec![1.0f32; 32];
    for _ in 0..5 {
        rt.run_f32("op_tanh_n32", &[Arg::F32(&a)]).unwrap();
    }
    assert_eq!(rt.stats().compiles, 1);
    assert_eq!(rt.stats().executions, 5);
}

#[test]
fn manifest_buckets_are_sorted_and_complete() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let m = &rt.manifest;
    for cell in ["lstm", "treelstm", "treefc"] {
        for h in [32usize, 64, 256, 512, 1024] {
            let b = m.buckets(cell, "cell_fwd", h);
            assert!(!b.is_empty(), "{cell} h={h}");
            assert!(b.windows(2).all(|w| w[0] < w[1]));
            // every fwd bucket has a matching bwd artifact
            for &bk in b {
                let bwd = cavs::runtime::Manifest::cell_name(cell, "cell_bwd", h, bk);
                assert!(m.has(&bwd), "{bwd} missing");
            }
        }
    }
    // param_grad bucket ladder exists for the paper cells
    for cell in ["lstm", "treelstm", "treefc"] {
        for h in [64usize, 256, 512, 1024] {
            assert!(
                m.buckets(cell, "param_grad", h).len() >= 2,
                "{cell} h={h} pgrad ladder"
            );
        }
    }
}

#[test]
fn manifest_bucket_for_picks_smallest_cover() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let m = &rt.manifest;
    assert_eq!(m.bucket_for("treelstm", "cell_fwd", 512, 1).unwrap(), 1);
    assert_eq!(m.bucket_for("treelstm", "cell_fwd", 512, 3).unwrap(), 4);
    assert_eq!(m.bucket_for("treelstm", "cell_fwd", 512, 1024).unwrap(), 1024);
    // beyond the ladder => max (engine chunks)
    assert_eq!(m.bucket_for("treelstm", "cell_fwd", 512, 9999).unwrap(), 1024);
    assert!(m.bucket_for("nope", "cell_fwd", 512, 1).is_err());
}

// ---------------------------------------------------------------------
// Fold preprocessing plan
// ---------------------------------------------------------------------

#[test]
fn fold_plan_levels_and_wiring_are_consistent() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut rng = Rng::new(11);
    let graphs: Vec<InputGraph> = (0..5)
        .map(|_| {
            let leaves = 2 + rng.below(10);
            synth::random_binary_tree(&mut rng, 20, leaves, 5)
        })
        .collect();
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let mut fold = Fold::new(&rt, 2);
    let plan = fold.preprocess(&refs, 2);

    let n: usize = graphs.iter().map(InputGraph::n).sum();
    // every vertex in exactly one level
    assert_eq!(plan.levels.iter().map(Vec::len).sum::<usize>(), n);
    // carry positions are a permutation
    let mut pos: Vec<u32> = plan.carry_pos.clone();
    pos.sort_unstable();
    assert_eq!(pos, (0..n as u32).collect::<Vec<_>>());
    // wiring points strictly below the current level's carry positions
    let mut level_start = 0usize;
    for (d, level) in plan.levels.iter().enumerate() {
        for (i, &v) in level.iter().enumerate() {
            assert_eq!(plan.carry_pos[v as usize] as usize, level_start + i);
            for slot in 0..2 {
                let w = plan.wiring[d][i * 2 + slot];
                if w != u32::MAX {
                    assert!((w as usize) < level_start, "wiring must point to an earlier depth");
                }
            }
        }
        level_start += level.len();
    }
}

#[test]
fn fold_thread_counts_produce_identical_plans() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut rng = Rng::new(12);
    let graphs: Vec<InputGraph> = (0..8)
        .map(|_| {
            let leaves = 2 + rng.below(12);
            synth::random_binary_tree(&mut rng, 20, leaves, 5)
        })
        .collect();
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let p1 = Fold::new(&rt, 1).preprocess(&refs, 2);
    let p4 = Fold::new(&rt, 4).preprocess(&refs, 2);
    assert_eq!(p1.levels, p4.levels);
    assert_eq!(p1.wiring, p4.wiring);
    assert_eq!(p1.carry_pos, p4.carry_pos);
}

// ---------------------------------------------------------------------
// monolithic scan padding
// ---------------------------------------------------------------------

#[test]
fn scan_static_rejects_overlong_and_counts_padding() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut model = Model::new(Cell::Lstm, 32, 50, HeadKind::LmPerVertex, 50, 3);
    let mut scan = ScanLm::new(&rt, UnrollMode::Static { t: 4 });

    // a 3-token sentence in a T=4 bs=2 artifact: padding waste accounted
    let toks = [1i32, 2, 3, 4];
    let g = InputGraph::chain(&toks[..3], &toks[1..]);
    let r = scan.run_minibatch(&mut model, &[&g]).unwrap();
    assert_eq!(r.n_labels, 3);
    assert_eq!(scan.steps_useful, 3);
    assert_eq!(scan.steps_computed, 8); // bs bucket 2 x T 4
    assert!(scan.padding_waste() > 0.5);

    // overlong sentence must be rejected, not silently truncated
    let toks6 = [1i32, 2, 3, 4, 5, 6, 7];
    let long = InputGraph::chain(&toks6[..6], &toks6[1..]);
    assert!(scan.run_minibatch(&mut model, &[&long]).is_err());
}

// ---------------------------------------------------------------------
// engine instrumentation (what Tables 1-2 measure)
// ---------------------------------------------------------------------

#[test]
fn serial_policy_launches_scale_with_vertices() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let data = Dataset::sst_like(3, 4, 20, 5);
    let refs: Vec<&InputGraph> = data.graphs.iter().collect();
    let n_vertices: usize = data.graphs.iter().map(InputGraph::n).sum();

    let mut model = Model::new(Cell::TreeLstm, 32, 20, HeadKind::ClassifierAtRoot, 5, 3);
    let mut eng = Engine::new(
        &rt,
        EngineOpts { policy: Policy::Serial, lazy_batching: false, ..Default::default() },
    );
    rt.reset_stats();
    eng.run_minibatch(&mut model, &refs).unwrap();
    let serial_execs = rt.stats().executions;

    let mut model2 = Model::new(Cell::TreeLstm, 32, 20, HeadKind::ClassifierAtRoot, 5, 3);
    let mut eng2 = Engine::new(
        &rt,
        EngineOpts { lazy_batching: false, ..Default::default() },
    );
    rt.reset_stats();
    eng2.run_minibatch(&mut model2, &refs).unwrap();
    let batched_execs = rt.stats().executions;

    // serial: >= 2 launches per vertex (fwd+bwd); batched: far fewer
    assert!(serial_execs as usize >= 2 * n_vertices);
    assert!(
        batched_execs * 2 < serial_execs,
        "batched {batched_execs} vs serial {serial_execs}"
    );
}

#[test]
fn memory_traffic_accounting_is_nonzero_and_resets() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let data = Dataset::sst_like(4, 3, 20, 5);
    let refs: Vec<&InputGraph> = data.graphs.iter().collect();
    let mut model = Model::new(Cell::TreeLstm, 32, 20, HeadKind::ClassifierAtRoot, 5, 3);
    let mut eng = Engine::new(&rt, EngineOpts::default());
    eng.run_minibatch(&mut model, &refs).unwrap();
    assert!(eng.traffic.bytes() > 0);
    assert!(eng.traffic.ops() > 0);
    assert!(eng.timers.memory_s > 0.0);
    assert!(eng.timers.compute_s > 0.0);
    eng.reset_counters();
    assert_eq!(eng.traffic.bytes(), 0);
    assert_eq!(eng.timers.total_s(), 0.0);
}

#[test]
fn engine_errors_cleanly_without_artifacts_for_h() {
    require_artifacts!();
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    // h=48 was never compiled: the engine must fail with a clear error,
    // not panic or compute garbage
    let mut model = Model::new(Cell::TreeLstm, 48, 20, HeadKind::ClassifierAtRoot, 5, 3);
    let g = synth::random_binary_tree(&mut Rng::new(1), 20, 3, 5);
    let mut eng = Engine::new(&rt, EngineOpts::default());
    let err = eng.run_minibatch(&mut model, &[&g]).unwrap_err();
    assert!(format!("{err}").contains("artifacts"), "{err}");
}

#[test]
fn oversized_frontier_is_chunked_to_max_bucket() {
    require_artifacts!();
    // 40 single-vertex graphs at quick h=32 (max bucket 4): the frontier
    // of 40 must be executed in 10 chunks, not rejected
    let rt = Runtime::new(&artifacts_dir()).unwrap();
    let graphs: Vec<InputGraph> = (0..40)
        .map(|i| {
            InputGraph::from_children(vec![vec![]], vec![i % 20], vec![-1], 1)
                .unwrap()
        })
        .collect();
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let mut model = Model::new(Cell::TreeLstm, 32, 20, HeadKind::ClassifierAtRoot, 5, 3);
    let mut eng = Engine::new(&rt, EngineOpts::default());
    let r = eng.run_minibatch(&mut model, &refs).unwrap();
    assert_eq!(r.n_vertices, 40);
    assert!(r.n_tasks >= 10);
    assert!(r.loss.is_finite());
}
