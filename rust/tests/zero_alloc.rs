//! Counting-allocator proof of the zero-steady-state-allocation invariant
//! (DESIGN.md §5): after a warm-up minibatch has grown every arena — task
//! blocks, index plans, owner buckets, shard traffic slots, state/grad
//! buffers — the host frontier forward+backward loop performs **zero**
//! heap allocations, on the sequential path and on the persistent-pool
//! path alike.
//!
//! This file deliberately contains a single test: the allocation counter
//! is process-global, so a sibling test running concurrently in the same
//! binary would pollute the measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use cavs::exec::parallel::{HostFrontier, HostTreeFc};
use cavs::exec::pool::{Sharder, WorkerPool};
use cavs::graph::{GraphBatch, InputGraph};
use cavs::scheduler::{schedule, Policy};
use cavs::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_frontier_fwd_bwd_loop_allocates_nothing() {
    // A batch wide enough that every sharded stage actually shards, with
    // shared structure (trees) so backward exercises the owner-sharded
    // scatter-add and pull-adjoint paths.
    let mut rng = Rng::new(42);
    let graphs: Vec<InputGraph> = (0..8)
        .map(|_| {
            let len = 6;
            let toks: Vec<i32> =
                (0..len).map(|_| rng.below(20) as i32).collect();
            let labs = vec![-1; len];
            InputGraph::chain(&toks, &labs)
        })
        .collect();
    let refs: Vec<&InputGraph> = graphs.iter().collect();
    let batch = GraphBatch::new(&refs, 1);
    let tasks = schedule(&batch, Policy::Batched, &[1, 2, 4, 8, 16]);
    let h = 8;
    let cell = HostTreeFc::random(h, 1, &mut rng);
    let xtable: Vec<f32> = (0..20 * h).map(|_| rng.normal_f32(0.5)).collect();

    for threads in [1usize, 2] {
        let pool = WorkerPool::new(threads);
        let ex = if threads == 1 {
            Sharder::Sequential
        } else {
            Sharder::Pool(&pool)
        };
        let mut hf = HostFrontier::new();
        // Warm-up: the first minibatch grows every arena to its
        // high-water mark; the second proves the mark is stable.
        for _ in 0..2 {
            hf.run(&batch, &tasks, &cell, &xtable, ex, true);
        }
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..3 {
            hf.run(&batch, &tasks, &cell, &xtable, ex, true);
        }
        let after = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state fwd+bwd heap-allocated (threads={threads})"
        );
        // sanity: the runs did real work
        assert!(hf.states().as_slice().iter().any(|&v| v != 0.0));
        assert!(hf.grads().unwrap().as_slice().iter().any(|&v| v != 0.0));
    }

    // The Program interpreter obeys the same invariant — and since PR 5
    // `spec.random_cell` binds the compiled OptProgram plan, so this
    // measures the **optimized level path**: level tapes, blocked GEMM
    // sweeps, fused elementwise groups and the level parameter pass all
    // live on preplanned arenas. Sequential and pooled alike.
    let spec = cavs::models::CellSpec::lookup("gru", h).unwrap();
    let pc = spec.random_cell(&mut rng, 0.2).unwrap();
    assert!(pc.is_optimized(), "spec cells run the compiled plan");
    {
        let pool2 = WorkerPool::new(2);
        for (what, ex) in [
            ("sequential", Sharder::Sequential),
            ("pooled", Sharder::Pool(&pool2)),
        ] {
            let mut hf = HostFrontier::new();
            for _ in 0..2 {
                hf.run(&batch, &tasks, &pc, &xtable, ex, true);
            }
            let before = ALLOCS.load(Ordering::SeqCst);
            for _ in 0..3 {
                hf.run(&batch, &tasks, &pc, &xtable, ex, true);
            }
            let after = ALLOCS.load(Ordering::SeqCst);
            assert_eq!(
                after - before,
                0,
                "steady-state optimized fwd+bwd+pgrad heap-allocated ({what})"
            );
            assert!(hf.param_grads().unwrap().iter().flatten().any(|&v| v != 0.0));
        }
    }

    // The SIMD kernel path (DESIGN.md §11) binds packed weight panels
    // and a transposed copy at instantiation and refreshes them in place
    // via `sync_opt` — so a steady-state train-style loop (fwd+bwd plus
    // an SGD-shaped `sync_opt` per minibatch, fast-math activations on)
    // still allocates nothing.
    let mut pc_fast = spec.random_cell(&mut rng, 0.2).unwrap();
    pc_fast.set_math(cavs::exec::MathMode::Fast);
    {
        let mut hf = HostFrontier::new();
        for _ in 0..2 {
            hf.run(&batch, &tasks, &pc_fast, &xtable, Sharder::Sequential, true);
            pc_fast.sync_opt();
        }
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..3 {
            hf.run(&batch, &tasks, &pc_fast, &xtable, Sharder::Sequential, true);
            pc_fast.sync_opt();
        }
        let after = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state fast-math packed-kernel loop heap-allocated"
        );
        assert!(hf.param_grads().unwrap().iter().flatten().any(|&v| v != 0.0));
    }

    // ...and the reference (no_opt) interpreter path stays clean too.
    let pc_ref = spec.random_cell_unoptimized(&mut rng, 0.2).unwrap();
    let mut hf = HostFrontier::new();
    for _ in 0..2 {
        hf.run(&batch, &tasks, &pc_ref, &xtable, Sharder::Sequential, true);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..3 {
        hf.run(&batch, &tasks, &pc_ref, &xtable, Sharder::Sequential, true);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state reference interpreter fwd+bwd+pgrad heap-allocated"
    );
    assert!(hf.param_grads().unwrap().iter().flatten().any(|&v| v != 0.0));

    // Real training steady state (DESIGN.md §14): the full Adam +
    // classifier-loss-head minibatch loop — forward, in-place softmax
    // seeding, structural backward, sequential Adam over every parameter
    // slot plus the embedding table, `sync_opt` refresh — allocates
    // nothing once the warm-up steps have sized the moment buffers.
    {
        use cavs::train::{Adam, LossHead, LossStats, Optimizer as _};
        let labeled: Vec<InputGraph> = {
            let mut lrng = Rng::new(43);
            (0..8)
                .map(|i| {
                    let toks: Vec<i32> =
                        (0..6).map(|_| lrng.below(20) as i32).collect();
                    let labs = vec![-1; 6];
                    let mut g = InputGraph::chain(&toks, &labs);
                    g.root_label = (i % 4) as i32;
                    g
                })
                .collect()
        };
        let lrefs: Vec<&InputGraph> = labeled.iter().collect();
        let lbatch = GraphBatch::new(&lrefs, 1);
        let ltasks = schedule(&lbatch, Policy::Batched, &[1, 2, 4, 8, 16]);
        let mut train_cell = spec.random_cell(&mut rng, 0.2).unwrap();
        let mut xt = xtable.clone();
        let mut adam = Adam::new(0.01);
        let head = LossHead::ClassifierAtRoot { n_classes: 4 };
        let mut hf = HostFrontier::new();
        let mut stats = LossStats::default();
        let mut before = 0u64;
        for it in 0..5 {
            if it == 2 {
                before = ALLOCS.load(Ordering::SeqCst);
            }
            hf.run_with_seed(
                &lbatch,
                &ltasks,
                &train_cell,
                &xt,
                Sharder::Sequential,
                true,
                |b, s, g| stats = head.loss_and_seed(b, s, g),
            );
            adam.begin_step();
            let np = {
                let params = train_cell.params_mut();
                let pg = hf.param_grads().unwrap();
                for (slot, (p, g)) in params.iter_mut().zip(pg).enumerate() {
                    adam.update(slot, p, g);
                }
                params.len()
            };
            train_cell.sync_opt();
            if let Some(xg) = hf.x_grads() {
                adam.update(np, &mut xt, xg);
            }
        }
        let after = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state Adam + loss-head training loop heap-allocated"
        );
        assert_eq!(stats.n_labels, 8, "every root was supervised");
        assert!(stats.loss.is_finite() && stats.loss > 0.0);
        assert_eq!(adam.steps(), 5);
    }

    // Observability (DESIGN.md §12): with the span tracer AND the
    // per-op-class profiler turned on, the same compiled-path loop still
    // allocates nothing — each thread's ring is preallocated on its first
    // span (warm-up territory) and overwrites oldest thereafter; the
    // profiler is a fixed array of atomics. Sequential and pooled alike
    // (the pool's worker threads get their rings during warm-up too).
    cavs::obs::trace::set_ring_capacity(512);
    cavs::obs::trace::set_enabled(true);
    cavs::obs::profile::set_enabled(true);
    {
        let pool2 = WorkerPool::new(2);
        for (what, ex) in [
            ("sequential", Sharder::Sequential),
            ("pooled", Sharder::Pool(&pool2)),
        ] {
            let mut hf = HostFrontier::new();
            for _ in 0..2 {
                hf.run(&batch, &tasks, &pc, &xtable, ex, true);
            }
            let spans_before = cavs::obs::trace::total_recorded();
            let before = ALLOCS.load(Ordering::SeqCst);
            for _ in 0..3 {
                hf.run(&batch, &tasks, &pc, &xtable, ex, true);
            }
            let after = ALLOCS.load(Ordering::SeqCst);
            assert_eq!(
                after - before,
                0,
                "steady-state traced+profiled fwd+bwd heap-allocated ({what})"
            );
            assert!(
                cavs::obs::trace::total_recorded() > spans_before,
                "the traced window recorded no spans ({what})"
            );
        }
    }
    cavs::obs::profile::set_enabled(false);
    cavs::obs::trace::set_enabled(false);
    assert!(
        cavs::obs::profile::snapshot().iter().any(|&(_, ns, calls)| {
            ns > 0 && calls > 0
        }),
        "the profiled window attributed no kernel time"
    );
}
