//! Vendored minimal reimplementation of the `anyhow` API surface this
//! workspace uses: `Error`, `Result`, `anyhow!`, `bail!`, `ensure!`, and
//! the `Context` extension trait. Kept dependency-free so a clean checkout
//! builds with no network access; replace the path dependency in the root
//! Cargo.toml with the crates.io `anyhow` to switch to the real thing.
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// An error chain: `chain[0]` is the outermost context message, later
/// entries are the underlying causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: std::error::Error>(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or("error"))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or("error"))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion used by [`super::Context`] so the trait covers
    /// both `Result<T, E: std::error::Error>` and `Result<T, Error>`
    /// without overlapping impls (`Error` is not a `std::error::Error`).
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from_std(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on results and options.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        ctx: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        ctx: C,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        ctx: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_wraps_both_error_kinds() {
        let a: Result<(), std::io::Error> = Err(io_err());
        let e = a.context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(e.root_cause(), "gone");

        let b: Result<()> = Err(anyhow!("inner"));
        let e = b.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1");
        assert_eq!(e.root_cause(), "inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).is_err());
        assert!(format!("{:?}", f(11).unwrap_err()).contains("too big"));
    }
}
