//! Stub of the PJRT/XLA binding surface used by `cavs::runtime` (see
//! README.md). Host-side bookkeeping (clients, buffers, literals) works;
//! compiling or executing an HLO program returns [`Error::Unavailable`]
//! so callers fail with a clear message instead of a link error.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// The operation needs the real XLA extension, which this stub build
    /// does not link.
    Unavailable(String),
    /// Host-side misuse (shape mismatch, bad literal access).
    Invalid(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real PJRT bindings \
                 (this build vendors the offline stub; see vendor/xla/README.md)"
            ),
            Error::Invalid(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

/// Element types accepted by host<->device marshalling.
pub trait NativeType: Copy {
    const BYTES: usize;
    fn from_le(bytes: &[u8]) -> Self;
    fn write_le(self, out: &mut Vec<u8>);
}

impl NativeType for f32 {
    const BYTES: usize = 4;
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl NativeType for i32 {
    const BYTES: usize = 4;
    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

/// Parsed HLO module (text interchange). The stub only records the path.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let _ = path;
        unavailable("parsing HLO text")
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A device buffer. The stub keeps the host copy so uploads round-trip.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    bytes: Vec<u8>,
    dims: Vec<usize>,
}

impl PjRtBuffer {
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal { bytes: self.bytes.clone(), tuple: None })
    }
}

#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute over borrowed buffers; `result[replica][output]`.
    pub fn execute_b(&self, args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let _ = args;
        unavailable("executing a PJRT program")
    }
}

#[derive(Debug, Clone)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an HLO computation")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let elements: usize = dims.iter().product::<usize>().max(1);
        if elements != data.len() {
            return Err(Error::Invalid(format!(
                "buffer has {} elements but dims {:?} imply {}",
                data.len(),
                dims,
                elements
            )));
        }
        let mut bytes = Vec::with_capacity(data.len() * T::BYTES);
        for &v in data {
            v.write_le(&mut bytes);
        }
        Ok(PjRtBuffer { bytes, dims: dims.to_vec() })
    }
}

/// A host-side value read back from the device.
#[derive(Debug, Clone)]
pub struct Literal {
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn size_bytes(&self) -> usize {
        self.bytes.len()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.bytes.len() % T::BYTES != 0 {
            return Err(Error::Invalid(format!(
                "literal of {} bytes is not a whole number of {}-byte elements",
                self.bytes.len(),
                T::BYTES
            )));
        }
        Ok(self.bytes.chunks_exact(T::BYTES).map(T::from_le).collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(parts) => Ok(parts.clone()),
            None => Err(Error::Invalid(
                "literal is not a tuple (stub literals never are)".to_string(),
            )),
        }
    }

    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let v = self.to_vec::<T>()?;
        if v.len() != dst.len() {
            return Err(Error::Invalid(format!(
                "copy_raw_to: literal has {} elements, destination {}",
                v.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(&v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_round_trip_on_host() {
        let c = PjRtClient::cpu().unwrap();
        let buf = c
            .buffer_from_host_buffer(&[1.0f32, 2.0, 3.0, 4.0], &[2, 2], None)
            .unwrap();
        assert_eq!(buf.dims(), &[2, 2]);
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.size_bytes(), 16);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let mut out = [0.0f32; 4];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0f32; 3], &[2, 2], None).is_err());
    }

    #[test]
    fn execution_paths_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto {
            _path: String::new(),
        });
        assert!(c.compile(&comp).is_err());
    }
}
