//! Repo automation tasks (`cargo run -p xtask -- <task>`). Std only —
//! the lint must run on the hermetic CI runners with no extra deps.
//!
//! `safety-lint` enforces the unsafe-hygiene half of DESIGN.md §13: every
//! `unsafe` block / `unsafe impl` in `rust/src` must carry a `SAFETY:`
//! comment naming at least one invariant registered in
//! `rust/src/analysis/invariants.rs` (as `[inv:<tag>]`). Declarations of
//! `unsafe fn` are exempt — they *create* an obligation (documented as
//! their safety contract) rather than discharging one; the operations
//! inside their bodies sit in their own tagged blocks.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("safety-lint") => safety_lint(),
        Some(t) => {
            eprintln!("unknown task '{t}'");
            eprintln!("tasks:\n  safety-lint   check SAFETY comments on every unsafe site");
            ExitCode::FAILURE
        }
        None => {
            eprintln!("usage: cargo run -p xtask -- <task>");
            eprintln!("tasks:\n  safety-lint   check SAFETY comments on every unsafe site");
            ExitCode::FAILURE
        }
    }
}

fn safety_lint() -> ExitCode {
    let root = repo_root();
    let inv_file = root.join("rust/src/analysis/invariants.rs");
    let tags = match std::fs::read_to_string(&inv_file) {
        Ok(src) => registered_tags(&src),
        Err(e) => {
            eprintln!("safety-lint: cannot read {}: {e}", inv_file.display());
            return ExitCode::FAILURE;
        }
    };
    if tags.is_empty() {
        eprintln!("safety-lint: no invariant tags found in {}", inv_file.display());
        return ExitCode::FAILURE;
    }

    let mut files = Vec::new();
    collect_rs(&root.join("rust/src"), &mut files);
    files.sort();

    let mut violations = Vec::new();
    let mut sites = 0usize;
    for f in &files {
        let src = match std::fs::read_to_string(f) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("safety-lint: cannot read {}: {e}", f.display());
                return ExitCode::FAILURE;
            }
        };
        sites += lint_file(f, &src, &tags, &mut violations);
    }

    if violations.is_empty() {
        println!(
            "safety-lint: {} unsafe sites across {} files, all tagged with registered invariants ({} tags)",
            sites,
            files.len(),
            tags.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!(
            "safety-lint: {} violation(s). Every unsafe block/impl needs a `// SAFETY:` comment \
             naming a registered invariant `[inv:<tag>]` (see rust/src/analysis/invariants.rs \
             and DESIGN.md §13).",
            violations.len()
        );
        ExitCode::FAILURE
    }
}

/// The repository root: walk up from CWD until Cargo.toml + rust/src exist.
fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().expect("cwd");
    loop {
        if dir.join("Cargo.toml").is_file() && dir.join("rust/src").is_dir() {
            return dir;
        }
        if !dir.pop() {
            panic!("repo root (Cargo.toml + rust/src) not found above cwd");
        }
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Extract every `tag: "<kebab>"` literal from invariants.rs. The quote
/// must follow `tag:` directly (whitespace only in between) so prose
/// mentions of `tag:` and the `lookup(tag: &str)` signature don't pair
/// up with an unrelated later string literal.
fn registered_tags(src: &str) -> Vec<String> {
    let mut tags = Vec::new();
    let mut rest = src;
    while let Some(i) = rest.find("tag:") {
        rest = &rest[i + 4..];
        let after_ws = rest.trim_start();
        let Some(lit) = after_ws.strip_prefix('"') else { continue };
        let Some(q1) = lit.find('"') else { break };
        tags.push(lit[..q1].to_string());
        rest = &lit[q1 + 1..];
    }
    tags
}

/// A code line's content with line comments stripped (no string-literal
/// awareness needed: no shipped source puts `unsafe` in a string).
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

fn is_comment_line(line: &str) -> bool {
    let t = line.trim_start();
    t.starts_with("//")
}

/// Whether the stripped code contains `unsafe` as its own token.
fn has_unsafe_token(code: &str) -> bool {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(i) = code[from..].find("unsafe") {
        let at = from + i;
        let pre_ok = at == 0 || !is_ident(b[at - 1]);
        let end = at + "unsafe".len();
        let post_ok = end == b.len() || !is_ident(b[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whether this token occurrence is an `unsafe fn` declaration (possibly
/// `unsafe extern "C" fn`): creating, not discharging, an obligation.
fn is_unsafe_fn_decl(code: &str) -> bool {
    if let Some(i) = code.find("unsafe") {
        let after = code[i + "unsafe".len()..].trim_start();
        return after.starts_with("fn ")
            || after.starts_with("fn(")
            || after.starts_with("extern");
    }
    false
}

/// Lint one file; returns the number of unsafe sites checked and pushes
/// human-readable violations.
fn lint_file(
    path: &Path,
    src: &str,
    tags: &[String],
    violations: &mut Vec<String>,
) -> usize {
    let lines: Vec<&str> = src.lines().collect();
    let mut sites = 0usize;
    for (idx, line) in lines.iter().enumerate() {
        if is_comment_line(line) {
            continue;
        }
        let code = code_part(line);
        if !has_unsafe_token(code) || is_unsafe_fn_decl(code) {
            continue;
        }
        sites += 1;
        // gather the contiguous comment block directly above (plus any
        // trailing comment on the line itself)
        let mut block = String::new();
        if let Some(i) = line.find("//") {
            block.push_str(&line[i..]);
            block.push('\n');
        }
        let mut j = idx;
        while j > 0 && is_comment_line(lines[j - 1]) {
            j -= 1;
        }
        for l in &lines[j..idx] {
            block.push_str(l);
            block.push('\n');
        }
        let loc = format!("{}:{}", path.display(), idx + 1);
        if !block.contains("SAFETY") {
            violations.push(format!("{loc}: unsafe site without a SAFETY comment"));
            continue;
        }
        let named: Vec<&str> = inv_refs(&block);
        if named.is_empty() {
            violations.push(format!(
                "{loc}: SAFETY comment names no invariant ([inv:<tag>] missing)"
            ));
            continue;
        }
        for t in named {
            if !tags.iter().any(|k| k == t) {
                violations.push(format!(
                    "{loc}: SAFETY comment references unregistered invariant '[inv:{t}]'"
                ));
            }
        }
    }
    sites
}

/// Every `[inv:<tag>]` reference inside a comment block.
fn inv_refs(block: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut rest = block;
    while let Some(i) = rest.find("[inv:") {
        let after = &rest[i + 5..];
        let Some(j) = after.find(']') else { break };
        out.push(&after[..j]);
        rest = &after[j + 1..];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_extraction_reads_quoted_literals() {
        let src = r#"
            Invariant { tag: "shard-rows", what: "w", proved_by: "p" },
            Invariant { tag: "owner-partition", what: "w", proved_by: "p" },
        "#;
        assert_eq!(registered_tags(src), vec!["shard-rows", "owner-partition"]);
        // prose/signature mentions of `tag:` must not swallow a later
        // unrelated string literal
        let noisy = r#"
            //! the `tag:` literals below
            pub fn lookup(tag: &str) -> bool { tag == "x" }
            Invariant { tag: "pool-quiesce", what: "w", proved_by: "p" },
        "#;
        assert_eq!(registered_tags(noisy), vec!["pool-quiesce"]);
    }

    #[test]
    fn unsafe_token_matching_ignores_identifiers_and_comments() {
        assert!(has_unsafe_token("let x = unsafe { y };"));
        assert!(has_unsafe_token("unsafe impl Send for T {}"));
        assert!(!has_unsafe_token("#![deny(unsafe_op_in_unsafe_fn)]"));
        assert!(!has_unsafe_token("let unsafer = 1;"));
        assert!(!has_unsafe_token(code_part("// unsafe in a comment")));
    }

    #[test]
    fn unsafe_fn_declarations_are_exempt() {
        assert!(is_unsafe_fn_decl("pub(crate) unsafe fn get(&self) {}"));
        assert!(is_unsafe_fn_decl("unsafe fn gemm<const F: bool>("));
        assert!(!is_unsafe_fn_decl("let x = unsafe { f() };"));
        assert!(!is_unsafe_fn_decl("unsafe impl Send for T {}"));
    }

    #[test]
    fn lint_accepts_tagged_and_rejects_untagged() {
        let tags = vec!["shard-rows".to_string()];
        let good = "fn f() {\n    // SAFETY: [inv:shard-rows] disjoint.\n    unsafe { g() }\n}\n";
        let mut v = Vec::new();
        assert_eq!(lint_file(Path::new("good.rs"), good, &tags, &mut v), 1);
        assert!(v.is_empty(), "{v:?}");

        let missing = "fn f() {\n    unsafe { g() }\n}\n";
        let mut v = Vec::new();
        lint_file(Path::new("m.rs"), missing, &tags, &mut v);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("without a SAFETY comment"));

        let untagged = "fn f() {\n    // SAFETY: fine, trust me.\n    unsafe { g() }\n}\n";
        let mut v = Vec::new();
        lint_file(Path::new("u.rs"), untagged, &tags, &mut v);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("names no invariant"));

        let unknown =
            "fn f() {\n    // SAFETY: [inv:not-a-tag] nope.\n    unsafe { g() }\n}\n";
        let mut v = Vec::new();
        lint_file(Path::new("k.rs"), unknown, &tags, &mut v);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("unregistered invariant"));
    }
}
